"""HLO collective parser + roofline arithmetic (the §Roofline substrate)."""
import jax
import jax.numpy as jnp
import pytest

from repro import hardware as hw
from repro import roofline as RL
from repro.configs import SHAPES, get_arch
from repro.utils.hlo import parse_collectives

SAMPLE_HLO = """
HloModule test
  %all-reduce.1 = f32[16,1024]{1,0} all-reduce(f32[16,1024]{1,0} %p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[32,512]{1,0} all-gather(bf16[16,512]{1,0} %p1), replica_groups=[2,2]<=[4], dimensions={0}
  %rs.3 = f32[8,128]{1,0} reduce-scatter(f32[16,128]{1,0} %p2), replica_groups={{0,1}}, dimensions={0}
  %cp = u32[64]{0} collective-permute(u32[64]{0} %p3), source_target_pairs={{0,1}}
  ROOT %aa = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(f32[4,4]{1,0} %a, f32[4,4]{1,0} %b), replica_groups={{0,1}}
  %ar-start = f32[100]{0} all-reduce-start(f32[100]{0} %x), replica_groups={{0,1}}
  %ar-done = f32[100]{0} all-reduce-done(f32[100]{0} %ar-start)
"""


def test_parser_counts_and_bytes():
    st = parse_collectives(SAMPLE_HLO)
    assert st.counts["all-reduce"] == 2          # .1 and -start (not -done)
    assert st.counts["all-gather"] == 1
    assert st.counts["reduce-scatter"] == 1
    assert st.counts["collective-permute"] == 1
    assert st.counts["all-to-all"] == 1
    # all-reduce.1: 2 * 16*1024*4 * 3/4
    expected_ar1 = 2 * 16 * 1024 * 4 * 3 / 4
    # -start: 2 * 100*4 * 1/2
    expected_ar2 = 2 * 100 * 4 * 1 / 2
    assert st.bytes_by_kind["all-reduce"] == pytest.approx(
        expected_ar1 + expected_ar2)
    # all-gather: result 32*512*2 bytes * (g-1)/g with iota groups [2,2]->g=2
    assert st.bytes_by_kind["all-gather"] == pytest.approx(
        32 * 512 * 2 * 0.5)
    # all-to-all: tuple of two f32[4,4] = 128 bytes * 1/2
    assert st.bytes_by_kind["all-to-all"] == pytest.approx(128 * 0.5)
    assert st.bytes_by_kind["collective-permute"] == pytest.approx(64 * 4)


def test_parser_on_real_compiled_module():
    import os
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P, AxisType
        from repro.utils.hlo import parse_collectives
        mesh = jax.make_mesh((4,), ("m",), axis_types=(AxisType.Auto,))
        def f(x, w):
            y = jnp.einsum("bd,df->bf", x, w)
            return jnp.einsum("bf,fd->bd", y, w.T)  # forces an all-reduce
        c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, None)),
                                     NamedSharding(mesh, P(None, "m")))).lower(
            jax.ShapeDtypeStruct((8, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 128), jnp.float32)).compile()
        st = parse_collectives(c.as_text())
        assert sum(st.counts.values()) >= 1, st.counts
        assert st.total_bytes > 0
        print("PARSER_OK")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PARSER_OK" in r.stdout, r.stderr[-1500:]


def test_roofline_terms_and_bottleneck():
    cfg = get_arch("yi-6b")
    shape = SHAPES["train_4k"]
    rep = RL.analyze_costs(
        flops=1e15, nbytes=1e12, coll_bytes=1e10, coll_counts={},
        cfg=cfg, shape=shape, mesh_name="16x16", chips=256)
    assert rep.t_compute == pytest.approx(1e15 / hw.PEAK_FLOPS_BF16)
    assert rep.t_memory == pytest.approx(1e12 / hw.HBM_BW)
    assert rep.t_collective == pytest.approx(1e10 / hw.ICI_LINK_BW)
    assert rep.bottleneck == "compute"
    assert rep.t_step == rep.t_compute
    assert rep.t_step_serial > rep.t_step


def test_model_flops_conventions():
    cfg = get_arch("olmoe-1b-7b")  # MoE: active < total
    counts = cfg.param_counts()
    assert counts["active"] < 0.35 * counts["total"]
    t = RL.model_flops(cfg, SHAPES["train_4k"])
    p = RL.model_flops(cfg, SHAPES["prefill_32k"])
    d = RL.model_flops(cfg, SHAPES["decode_32k"])
    tokens_t = 256 * 4096
    assert t == pytest.approx(6 * counts["active"] * tokens_t)
    assert p == pytest.approx(2 * counts["active"] * 32 * 32768)
    assert d == pytest.approx(2 * counts["active"] * 128)
