"""Property suite for the hierarchical fleet layer.

Three invariants, each pinned two ways — a deterministic parametrized
pass that always runs, and a randomized hypothesis pass when the
optional dependency is installed (same skip idiom as
``test_screen_properties.py``):

  * partition exactness — the generator's regions cover every site
    exactly once, and each region's farm queue is pinned inside it;
  * record-flow conservation — RAP trunks and per-region edge pipes
    redistribute *time* (contention, delay), never *records*: the
    source-side ledger keys (produced / fetched / overflow / unread)
    are identical between a hierarchical fleet and its region-stripped
    flat twin on the same plan, and every fetched record in each run is
    accounted for by exactly one sink key;
  * seeded determinism — ``generate_fleet`` is a pure function of its
    :class:`FleetGenSpec`.
"""
import dataclasses

import pytest

from repro.placement.plan import PlacementPlan
from repro.region import FleetGenSpec, generate_fleet, hier_fleet_spec

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container without the optional test dep
    HAVE_HYPOTHESIS = False

# the cross-fleet-topology-invariant ledger keys: produced at the farms
# and fetched/overflowed/left-unread at the edge, all upstream of any
# transport tier
_SOURCE_KEYS = ("produced", "fetched", "overflow", "unread")
# every fetched record must land in exactly one of these
_SINK_KEYS = ("processed_edge", "processed_dc", "dropped_dc",
              "inflight_dc", "buffered", "evicted_stored", "evicted_lost")


def _check_partition(gen: FleetGenSpec) -> None:
    spec = generate_fleet(gen)
    fleet = hier_fleet_spec(spec)
    seen = [s for r in fleet.regions for s in r.sites]
    assert len(seen) == len(set(seen)) == len(fleet.site_names)
    assert set(seen) == set(fleet.site_names)
    region_names = {r.name for r in fleet.regions}
    for name in fleet.site_names:            # region_of is total + unique
        assert fleet.region_of(name) in region_names
    # every farm queue is pinned inside the region whose chain reads it
    for farm in spec.farms:
        site = fleet.farm_site(farm.queue)
        assert fleet.region_of(site) == f"region-{farm.queue[1:3]}"


def _check_flow_conservation(gen: FleetGenSpec, chips: int) -> None:
    """The hierarchy moves contention around (per-region edge pipes +
    RAP trunks vs one shared uplink) so *timing*-derived keys like
    ``dropped_dc`` may legitimately differ from the flat twin — but the
    source-side counts cannot, and each run must account for every
    fetched record."""
    spec = generate_fleet(gen)
    names = [s.name for s in spec.services]
    plan = PlacementPlan.all_dc(names, chips=chips, dvfs_f=1.0)

    hier = spec.compile().run_plan(plan)
    flat = dataclasses.replace(spec, regions=()).compile().run_plan(plan)
    ht, ft = hier.ledger.totals(), flat.ledger.totals()

    assert hier.ledger.conserved() and flat.ledger.conserved()
    for key in _SOURCE_KEYS:
        assert ht.get(key, 0) == ft.get(key, 0), key
    for totals in (ht, ft):
        assert totals["fetched"] == sum(totals.get(k, 0)
                                        for k in _SINK_KEYS)


def _check_determinism(gen: FleetGenSpec) -> None:
    a, b = generate_fleet(gen), generate_fleet(gen)
    assert a == b and a.to_dict() == b.to_dict()


# ---------------------------------------------------------------------
# deterministic pins — always run, hypothesis or not
# ---------------------------------------------------------------------
_PIN_GENS = [
    FleetGenSpec(n_sites=3, n_regions=1, services_per_region=1, seed=0,
                 drift="constant", horizon_s=600.0),
    FleetGenSpec(n_sites=8, n_regions=3, seed=42, drift="constant",
                 horizon_s=600.0),
    FleetGenSpec(n_sites=17, n_regions=4, services_per_region=2, seed=7,
                 drift="bursts", horizon_s=600.0, base_rate_hz=3.0),
]


@pytest.mark.parametrize("gen", _PIN_GENS,
                         ids=lambda g: f"{g.n_sites}x{g.n_regions}-s{g.seed}")
def test_partition_exactness_pins(gen):
    _check_partition(gen)


@pytest.mark.parametrize("gen,chips", [(_PIN_GENS[1], 4), (_PIN_GENS[2], 8)],
                         ids=["8x3-s42-c4", "17x4-s7-c8"])
def test_flow_conservation_pins(gen, chips):
    _check_flow_conservation(gen, chips)


@pytest.mark.parametrize("gen", _PIN_GENS,
                         ids=lambda g: f"{g.n_sites}x{g.n_regions}-s{g.seed}")
def test_generator_determinism_pins(gen):
    _check_determinism(gen)


# ---------------------------------------------------------------------
# randomized sweeps — hypothesis, when installed
# ---------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    _GEN = st.builds(
        FleetGenSpec,
        n_sites=st.integers(min_value=3, max_value=24),
        n_regions=st.integers(min_value=1, max_value=3),
        services_per_region=st.integers(min_value=1, max_value=3),
        seed=st.integers(min_value=0, max_value=2 ** 16),
        drift=st.sampled_from(("constant", "diurnal", "bursts")),
        horizon_s=st.just(600.0),
        base_rate_hz=st.floats(min_value=1.0, max_value=6.0),
    ).filter(lambda g: g.n_sites >= g.n_regions)

    @settings(max_examples=25, deadline=None)
    @given(gen=_GEN)
    def test_generator_regions_partition_sites_exactly(gen):
        _check_partition(gen)

    @settings(max_examples=20, deadline=None)
    @given(gen=_GEN)
    def test_generator_is_deterministic(gen):
        _check_determinism(gen)

    @settings(max_examples=6, deadline=None)
    @given(gen=st.builds(
        FleetGenSpec,
        n_sites=st.integers(min_value=4, max_value=10),
        n_regions=st.integers(min_value=2, max_value=3),
        seed=st.integers(min_value=0, max_value=255),
        drift=st.just("constant"),
        horizon_s=st.just(600.0),
    ).filter(lambda g: g.n_sites >= g.n_regions),
        chips=st.sampled_from((4, 8)))
    def test_trunks_conserve_record_flow(gen, chips):
        _check_flow_conservation(gen, chips)
