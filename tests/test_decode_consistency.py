"""prefill(t[:S]) + decode(t[S]) must equal forward(t[:S+1])'s next-token
logits. MoE archs run with unbounded capacity (capacity dropping is
batch-dependent by construction — see models/moe.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.moe as MOE
from repro.configs import get_arch, list_archs
from repro.data import make_batch
from repro.models import model as M

S, B = 24, 2


@pytest.mark.parametrize("name", list_archs())
def test_decode_matches_forward(name, monkeypatch):
    monkeypatch.setattr(MOE, "CAPACITY_FACTOR", 1000.0)
    cfg = get_arch(name).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    bd = make_batch(cfg, S + 1, B, step=0)
    tokens = jnp.asarray(bd["tokens"])
    extras = {k: jnp.asarray(v) for k, v in bd.items()
              if k in ("patches", "frames")}

    logits_full, _ = M.forward(cfg, params, {"tokens": tokens, **extras},
                               compute_dtype=jnp.float32)
    pre = {"tokens": tokens[:, :S], **extras}
    logits0, cache = M.prefill(cfg, params, pre, cache_len=S + 8,
                               compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits0),
                               np.asarray(logits_full[:, S - 1]),
                               atol=2e-3, rtol=1e-3)
    logits1, _ = M.decode_step(cfg, params, cache, tokens[:, S:S + 1], S,
                               compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits1),
                               np.asarray(logits_full[:, S]),
                               atol=2e-3, rtol=1e-3)
