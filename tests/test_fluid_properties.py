"""Property-based tests for the batched fluid ensemble engine
(repro.fluid): fluid-vs-DES agreement on randomly drawn small
scenarios, bit-identical jit vs eager execution, plan-batch permutation
invariance, evaluate purity, and seeded ensemble determinism.

Every property runs over a fixed case grid so the suite bites even
without hypothesis installed; when hypothesis is available the same
checks also run fuzzed (the test_screen_properties.py pattern)."""
import numpy as np
import pytest

pytest.importorskip("jax")
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: degrade to the fixed grid
    HAVE_HYPOTHESIS = False

from repro.fluid import FluidEngine, ScenarioEnsemble
from repro.placement import PlacementPlan, ServicePlacement
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.scenario import RateSpec, scenario

_SLO_KW = dict(soft_latency_s=2.0, hard_latency_s=10.0,
               soft_energy_j=0.5, hard_energy_j=10.0)


def _spec(base_hz: float = 4.0, n_things: int = 4, width_s: float = 60.0,
          burst: bool = False):
    """Two heterogeneous gateways + chained services, short horizon."""
    rate = (RateSpec.bursts(base_hz, 2.5 * base_hz, [(60.0, 150.0)])
            if burst else RateSpec.constant(base_hz))
    return (scenario("fluid-prop")
            .horizon(240.0)
            .site("gw-a", edge=EdgeSpec(name="gw-a"),
                  link=LinkSpec(uplink_bps=1e5, rtt_s=0.05,
                                record_bytes=256.0))
            .site("gw-b", edge=EdgeSpec(name="gw-b", flops_per_s=15e9),
                  link=LinkSpec(uplink_bps=8e4, rtt_s=0.08,
                                record_bytes=256.0))
            .farm(n_things=n_things, seed=5, rate=rate, site="gw-a")
            .service("agg", queue="neubotspeed", column="download_speed",
                     agg="max", width_s=width_s, slide_s=width_s / 2)
            .slo(**_SLO_KW).profile(flops_per_record=2e3)
            .service("smooth", queue="agg_out", column="value", agg="mean",
                     width_s=2 * width_s, slide_s=width_s)
            .fed_by("agg")
            .slo(**_SLO_KW).profile(flops_per_record=2e3)
            .build())


def _plans(names):
    """A diverse fixed plan batch over both gateways and the DC."""
    return [
        PlacementPlan.all_edge(names, site="gw-a"),
        PlacementPlan.all_edge(names, site="gw-b"),
        PlacementPlan.all_dc(names, chips=4),
        PlacementPlan.all_dc(names, chips=8),
        PlacementPlan({"agg": ServicePlacement("gw-a"),
                       "smooth": ServicePlacement("dc", chips=4)}),
        PlacementPlan({"agg": ServicePlacement("dc", chips=4),
                       "smooth": ServicePlacement("gw-b")}),
    ]


@pytest.fixture(scope="module")
def spec():
    return _spec()


@pytest.fixture(scope="module")
def engine(spec):
    return spec.compile()


@pytest.fixture(scope="module")
def fluid(engine):
    return FluidEngine.compile(engine)


# --------------------------------------------------------- DES agreement
def _check_des_agreement(base_hz, n_things, width_s, burst, plan_idx):
    """Fluid mean-VoS of the nominal realization stays within 5% of the
    exact DES — or both tiers agree the plan is infeasible. (Eager
    path: one-off scenarios should not pay an XLA trace each.)"""
    eng = _spec(base_hz, n_things, width_s, burst).compile()
    fl = FluidEngine.compile(eng)
    plan = _plans(list(eng.order))[plan_idx]
    f_vos = float(fl.evaluate([plan], jit=False).vos[0, 0])
    des = eng.run_plan(plan)
    if not des.feasible or not np.isfinite(f_vos):
        assert not des.feasible and not np.isfinite(f_vos)
        return
    assert abs(f_vos - des.vos) <= 0.05 * max(abs(des.vos), 1e-9)


@pytest.mark.parametrize("base_hz,n_things,width_s,burst,plan_idx", [
    (4.0, 4, 60.0, False, 0),
    (4.0, 4, 60.0, False, 2),
    (1.5, 2, 30.0, False, 4),
    (7.0, 4, 30.0, True, 1),
    (6.0, 2, 60.0, True, 3),
    (3.0, 4, 30.0, True, 5),
])
def test_fluid_tracks_des_fixed_grid(base_hz, n_things, width_s, burst,
                                     plan_idx):
    _check_des_agreement(base_hz, n_things, width_s, burst, plan_idx)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(base_hz=st.floats(1.0, 8.0), n_things=st.sampled_from([2, 4]),
           width_s=st.sampled_from([30.0, 60.0]), burst=st.booleans(),
           plan_idx=st.integers(0, 5))
    def test_fluid_tracks_des_fuzzed(base_hz, n_things, width_s, burst,
                                     plan_idx):
        _check_des_agreement(base_hz, n_things, width_s, burst, plan_idx)


# --------------------------------------------------------- jit identity
def test_jit_matches_eager_bit_identical(fluid, engine):
    """The jitted scan and the eager scan are the same float32 program:
    VoS, latency and drop trajectories agree bit-for-bit on a small
    batch (nominal realization)."""
    plans = _plans(list(engine.order))
    a = fluid.evaluate(plans, jit=True)
    b = fluid.evaluate(plans, jit=False)
    assert (a.vos == b.vos).all()
    assert (a.vos_service == b.vos_service).all()
    assert (a.lat_mean == b.lat_mean).all()
    assert (a.drop_frac == b.drop_frac).all()
    assert (a.vos_t == b.vos_t).all()


def test_jit_matches_eager_on_small_ensemble(fluid, engine, spec):
    """Same identity across a multi-realization ensemble batch."""
    ens = ScenarioEnsemble.from_spec(spec, n=4, seed=3, engine=engine)
    plans = _plans(list(engine.order))[:3]
    a = ens.evaluate(plans, jit=True)
    b = ens.evaluate(plans, jit=False)
    assert a.vos.shape == (5, 3)  # n=4 perturbed + the nominal member
    assert (a.vos == b.vos).all()
    assert (a.drop_frac == b.drop_frac).all()


# ------------------------------------------------- permutation invariance
def _check_permutation(fluid, engine, seed):
    """A plan's fluid score does not depend on its batch position or
    companions: every per-(realization, plan) output commutes with any
    permutation of the plan batch."""
    plans = _plans(list(engine.order))
    base = fluid.evaluate(plans)
    perm = np.random.default_rng(seed).permutation(len(plans))
    shuf = fluid.evaluate([plans[i] for i in perm])
    assert (shuf.vos == base.vos[:, perm]).all()
    assert (shuf.lat_mean == base.lat_mean[:, perm]).all()
    assert (shuf.drop_frac == base.drop_frac[:, perm]).all()
    assert [shuf.feasible[k] for k in range(len(perm))] == \
           [base.feasible[i] for i in perm]


@pytest.mark.parametrize("seed", range(6))
def test_plan_batch_permutation_invariance(fluid, engine, seed):
    _check_permutation(fluid, engine, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1))
    def test_plan_batch_permutation_invariance_fuzzed(fluid, engine, seed):
        _check_permutation(fluid, engine, seed)


def test_evaluate_is_pure(fluid, engine):
    """Repeated evaluation is bit-identical — no hidden state in the
    lowered arrays or the jit cache."""
    plans = _plans(list(engine.order))
    a = fluid.evaluate(plans)
    b = fluid.evaluate(plans)
    assert (a.vos == b.vos).all()
    assert (a.vos_t == b.vos_t).all()
    assert (a.drop_t == b.drop_t).all()


# ------------------------------------------------- ensemble determinism
def test_ensemble_deterministic_per_seed(fluid, engine, spec):
    """ScenarioEnsemble.from_spec is bit-deterministic per seed: the
    lowered realization arrays and the fluid scores match across
    constructions; a different seed perturbs them."""
    plans = _plans(list(engine.order))[:3]
    e1 = ScenarioEnsemble.from_spec(spec, n=5, seed=11, engine=engine)
    e2 = ScenarioEnsemble.from_spec(spec, n=5, seed=11, engine=engine)
    for k in e1.realizations:
        assert (np.asarray(e1.realizations[k])
                == np.asarray(e2.realizations[k])).all(), k
    assert (e1.evaluate(plans).vos == e2.evaluate(plans).vos).all()
    e3 = ScenarioEnsemble.from_spec(spec, n=5, seed=12, engine=engine)
    assert any((np.asarray(e1.realizations[k])
                != np.asarray(e3.realizations[k])).any()
               for k in e1.realizations)


def test_ensemble_realization_zero_is_nominal(fluid, engine, spec):
    """With include_nominal=True (the default) realization 0 carries the
    unperturbed base scenario: its scores match the single-realization
    nominal evaluate."""
    plans = _plans(list(engine.order))[:3]
    ens = ScenarioEnsemble.from_spec(spec, n=4, seed=7, engine=engine)
    nom = fluid.evaluate(plans)
    assert ens.evaluate(plans).vos[0] == pytest.approx(nom.vos[0], rel=1e-5)
