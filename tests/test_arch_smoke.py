"""Per-arch smoke tests (deliverable f): reduced config, one forward +
one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.data import make_batch
from repro.models import model as M
from repro.train import TrainHParams, init_train_state, make_train_step

B, S = 2, 32


def _batch(cfg):
    bd = make_batch(cfg, S, B, step=0)
    return {k: jnp.asarray(v) for k, v in bd.items()}


@pytest.mark.parametrize("name", list_archs())
def test_forward_and_train_step(name):
    cfg = get_arch(name).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)

    logits, aux = jax.jit(lambda p, b: M.forward(cfg, p, b))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    hp = TrainHParams(grad_accum=2, remat="full", total_steps=10)
    step = jax.jit(make_train_step(cfg, hp))
    state = init_train_state(params)
    state, metrics = step(state, batch)
    assert int(state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(state.params):
        assert np.all(np.isfinite(np.asarray(leaf))), name


@pytest.mark.parametrize("name", ["smollm-135m", "olmoe-1b-7b",
                                  "jamba-v0.1-52b", "mamba2-1.3b",
                                  "whisper-medium"])
def test_prefill_decode_shapes(name):
    cfg = get_arch(name).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    batch.pop("labels")
    logits, cache = jax.jit(
        lambda p, b: M.prefill(cfg, p, b, cache_len=S + 4))(params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = jax.jit(
        lambda p, c, t: M.decode_step(cfg, p, c, t, S))(params, cache, tok)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
