import os
import sys

# tests run single-device (the dry-run manages its own device count in a
# separate process — see launch/dryrun.py)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
