"""Gradient compression, straggler mitigation, sharding rules, optimizer,
data determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't crash collection
from hypothesis import given, settings, strategies as st

from repro.runtime.compression import (ErrorFeedbackState, compress_int8,
                                       decompress_int8, topk_compress)
from repro.runtime.straggler import BackupStepPolicy, StragglerMonitor


# ----------------------------------------------------------------- compression
@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_int8_error_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 10
    q, s = compress_int8(x)
    err = jnp.max(jnp.abs(decompress_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    out = topk_compress(x, frac=0.4)
    np.testing.assert_array_equal(np.asarray(out != 0),
                                  [False, True, False, True, False])


def test_error_feedback_is_unbiased_over_time():
    """With error feedback, cumulative transmitted ≈ cumulative true grads
    (the residual stays bounded)."""
    from jax.sharding import PartitionSpec as P
    from repro.runtime.compression import compressed_allreduce
    mesh = jax.make_mesh((1,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    g_true = jax.random.normal(jax.random.PRNGKey(0), (32,))
    ef = ErrorFeedbackState.init({"g": g_true})

    def step(g, ef):
        out, ef2 = compressed_allreduce({"g": g}, ef, "pod",
                                        scheme="int8+topk", topk_frac=0.25)
        return out["g"], ef2

    run = jax.shard_map(step, mesh=mesh, in_specs=(P(), P()),
                        out_specs=(P(), P()), check_vma=False)
    sent_total = jnp.zeros_like(g_true)
    for _ in range(20):
        out, ef = run(g_true, ef)
        sent_total = sent_total + out
    avg_err = float(jnp.mean(jnp.abs(sent_total / 20 - g_true)))
    assert avg_err < 0.15 * float(jnp.mean(jnp.abs(g_true)))


# ------------------------------------------------------------------ straggler
def test_straggler_detection_and_backup():
    mon = StragglerMonitor(n_hosts=4, window=10, slack=1.5)
    events = []
    for step in range(10):
        times = [1.0, 1.05, 0.95, 1.0]
        if step >= 6:
            times[2] = 5.0  # host 2 degrades
        events += mon.record_step(step, times)
    assert {e.host for e in events} == {2}
    assert mon.persistent_stragglers(threshold=3) == [2]

    pol = BackupStepPolicy(n_spares=1, redispatch_cost=0.1)
    eff = pol.effective_step_time([1.0, 1.0, 5.0, 1.0], deadline=1.6,
                                  typical=1.0)
    assert eff < 5.0 and pol.backups == 1 and pol.saved_s > 0


# ------------------------------------------------------------------- sharding
def test_spec_divisibility_rules():
    import os
    from jax.sharding import PartitionSpec as P
    from repro import sharding as shd
    mesh = jax.make_mesh((1,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    m = FakeMesh()
    # divisible: sharded; non-divisible: dropped
    s1 = shd.spec_for_leaf(m, ("embed", "mlp"), (4096, 14336),
                           shd.TRAIN_RULES)
    assert s1 == P("data", "model")
    s2 = shd.spec_for_leaf(m, ("embed", "heads", "head_dim"), (576, 9, 64),
                           shd.TRAIN_RULES)
    assert s2 == P("data",)  # 9 heads don't divide 16 -> dropped
    s3 = shd.spec_for_leaf(m, ("vocab_in", "embed_in"), (49408, 576),
                           shd.SERVE_RULES)
    assert s3 == P("model",)


def test_batch_axes_for():
    from repro import sharding as shd

    class M2:
        shape = {"pod": 2, "data": 16, "model": 16}
        axis_names = ("pod", "data", "model")

    assert shd.batch_axes_for(M2(), 256) == ("pod", "data")
    assert shd.batch_axes_for(M2(), 2) == "pod"
    assert shd.batch_axes_for(M2(), 1) is None


# ------------------------------------------------------------------ optimizer
def test_adamw_converges_quadratic():
    from repro.optim import adamw_init, adamw_update
    params = {"x": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(300):
        g = {"x": 2 * params["x"]}  # d/dx x^2
        params, opt = adamw_update(g, opt, params, lr=0.05, weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.05


def test_schedule_shapes():
    from repro.optim import cosine_schedule
    lr0 = float(cosine_schedule(0, 10, 100, 1.0))
    lr_peak = float(cosine_schedule(10, 10, 100, 1.0))
    lr_end = float(cosine_schedule(100, 10, 100, 1.0))
    assert lr0 < lr_peak and abs(lr_peak - 1.0) < 1e-6
    assert abs(lr_end - 0.1) < 1e-2


# ----------------------------------------------------------------------- data
def test_data_deterministic_and_shifted():
    from repro.data import SyntheticLM
    d = SyntheticLM(vocab_size=128, seq_len=16, seed=3)
    b1, b2 = d.batch(7, 4), d.batch(7, 4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    b3 = d.batch(8, 4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_adamw_bf16_moments_track_fp32():
    """bf16 moments (the 70B memory lever) stay close to fp32 moments."""
    from repro.optim import adamw_init, adamw_update
    p32 = {"x": jnp.asarray([5.0, -3.0, 0.7])}
    p16 = {"x": jnp.asarray([5.0, -3.0, 0.7])}
    o32 = adamw_init(p32)
    o16 = adamw_init(p16, moment_dtype=jnp.bfloat16)
    assert o16.mu["x"].dtype == jnp.bfloat16
    for i in range(300):
        g32 = {"x": 2 * p32["x"]}
        g16 = {"x": 2 * p16["x"]}
        p32, o32 = adamw_update(g32, o32, p32, lr=0.05, weight_decay=0.0)
        p16, o16 = adamw_update(g16, o16, p16, lr=0.05, weight_decay=0.0)
    # trajectories differ (moments carry ~3 significant digits) but both
    # must converge on the quadratic
    assert float(jnp.max(jnp.abs(p32["x"]))) < 0.05
    assert float(jnp.max(jnp.abs(p16["x"]))) < 0.3
