"""Checkpointing: atomicity, retention, restart-resume equivalence, and
elastic re-shard across different meshes (subprocess with 8 fake devices)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, FailureInjector,
                              latest_step, restore_checkpoint,
                              run_with_restarts, save_checkpoint)


def _state():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": {"mu": jnp.ones((3, 4)), "count": jnp.int32(7)},
            "blocks": (jnp.zeros((2, 3)),)}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 5, _state())
    restored, step = restore_checkpoint(d, _state())
    assert step == 5
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(_state())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, save_every=1, keep=2, async_write=False)
    for s in range(1, 6):
        mgr.maybe_save(s, _state())
    assert latest_step(d) == 5
    from repro.checkpoint.ckpt import all_steps
    assert all_steps(d) == [4, 5]


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"w": jnp.zeros((3, 3))})


def test_restart_resume_equivalence(tmp_path):
    """Training through injected failures must land on exactly the same
    state as an uninterrupted run (step-keyed data + checkpoints)."""
    def make_runner():
        def one_step(state, step):
            # deterministic toy update depending on step
            return {"w": state["w"] + (step + 1)}, {"w0": float(state["w"])}
        return one_step

    init = {"w": jnp.float32(0.0)}
    mgr_a = CheckpointManager(str(tmp_path / "a"), save_every=3,
                              async_write=False)
    sa, _, ra = run_with_restarts(
        init_state=init, train_one_step=make_runner(), ckpt_manager=mgr_a,
        n_steps=10, injector=FailureInjector(fail_steps=[4, 8]))
    mgr_b = CheckpointManager(str(tmp_path / "b"), save_every=3,
                              async_write=False)
    sb, _, rb = run_with_restarts(
        init_state=init, train_one_step=make_runner(), ckpt_manager=mgr_b,
        n_steps=10, injector=FailureInjector())
    assert ra == 2 and rb == 0
    assert float(sa["w"]) == float(sb["w"]) == sum(range(1, 11))


ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P, AxisType
    from repro.checkpoint import save_checkpoint, restore_checkpoint

    d = sys.argv[1]
    mesh1 = jax.make_mesh((2, 4), ("data", "model"),
                          axis_types=(AxisType.Auto,) * 2)
    mesh2 = jax.make_mesh((8, 1), ("data", "model"),
                          axis_types=(AxisType.Auto,) * 2)
    w = jnp.arange(64.0).reshape(8, 8)
    sharded = jax.device_put(w, NamedSharding(mesh1, P("data", "model")))
    save_checkpoint(d, 1, {"w": sharded})
    # elastic restore onto a DIFFERENT mesh shape
    tmpl = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    sh2 = {"w": NamedSharding(mesh2, P("data", None))}
    restored, step = restore_checkpoint(d, tmpl, shardings=sh2)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
    assert restored["w"].sharding.num_devices == 8
    print("ELASTIC_OK")
""")


def test_elastic_reshard_across_meshes(tmp_path):
    r = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT, str(tmp_path)],
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]
