"""Pipeline parallelism over the pod axis: GPipe schedule must equal the
sequential stack (subprocess with 2 fake devices)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import AxisType
    from repro.runtime.pp import pipeline_forward

    mesh = jax.make_mesh((2,), ("pod",), axis_types=(AxisType.Auto,))
    n_stages, n_micro, mb, d = 2, 4, 3, 16
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (n_stages, d, d)) * 0.3

    def stage_fn(p, h):
        return jnp.tanh(h @ p)

    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
    pp = pipeline_forward(stage_fn, n_stages, n_micro, mesh)
    y = pp(w, x)

    # sequential reference
    ref = x
    for s in range(n_stages):
        ref = jnp.tanh(ref @ w[s])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    print("PP_OK")
""")


def test_pipeline_matches_sequential():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PP_OK" in r.stdout, (r.stdout[-800:], r.stderr[-2000:])
