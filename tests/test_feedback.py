"""Closed-loop forecast calibration (repro.scenario.feedback): RLS
fitting, deadbands and staleness decay; correction injection into
ForecastModel and ScreeningModel ranking; the engine's realized-residual
feed (EpochObservation.realized_window); CalibrationLoop determinism
(same spec + seed -> identical correction history); the *signed*
search-regret telemetry (both signs); and the golden-regression pin of
the BENCH_online.json telemetry schema."""
import json
import math
import os

import pytest

from repro.online import (OnlineController, StaticController, ForecastModel)
from repro.pipeline import (Broker, Pipeline, ServiceConfig, StreamService,
                            WindowSpec)
from repro.online.drift import DriftingFarm, step_bursts
from repro.online.fleet import FleetSpec, SiteSpec
from repro.placement import PlacementPlan
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.placement.search import Evaluator, search_placement
from repro.scenario import (CalibrationLoop, EngineConfig, EpochObservation,
                            RateSpec, ScenarioEngine, ServiceCalibration,
                            ServiceCorrection, ServiceProfile, ServiceSLO,
                            scenario)

_ROOT = os.path.join(os.path.dirname(__file__), "..")

_SLO = ServiceSLO(soft_latency_s=2.0, hard_latency_s=10.0,
                  soft_energy_j=0.3, hard_energy_j=3.0)


# ---------------------------------------------------------------- RLS core
def test_rls_fits_persistent_linear_error():
    """Feeding y = 2x + 1 repeatedly converges the latency terms onto
    the line (clamps permitting) — the loop learns a persistent bias."""
    loop = CalibrationLoop(["svc"], stale_decay=1.0)
    for k, x in enumerate([1.0, 2.0, 1.5, 2.5, 1.0, 2.0, 1.5, 2.5] * 3):
        loop.observe(k, {"svc": {"tier": "edge", "lat_s": x}},
                     {"svc": {"lat_mean_s": 2.0 * x + 1.0, "completed": 5,
                              "dropped": 0, "inflight": 0, "vos": 1.0}})
    c = loop.correction("svc").edge
    assert c.q_mult == pytest.approx(2.0, rel=0.25)
    assert c.latency(2.0) == pytest.approx(5.0, rel=0.2)
    # the DC tier never learned anything: still identity
    assert loop.correction("svc").dc.is_identity


def test_deadband_keeps_identity_on_small_error():
    """A well-calibrated forecast (realized ~= predicted) must produce
    *exactly* identity corrections, not epsilon perturbations."""
    loop = CalibrationLoop(["svc"])
    for k in range(8):
        loop.observe(k, {"svc": {"tier": "edge", "lat_s": 1.0}},
                     {"svc": {"lat_mean_s": 1.05, "completed": 5,
                              "dropped": 0, "inflight": 0, "vos": 1.0}})
    assert loop.correction("svc").edge.is_identity


def test_drop_offset_learns_and_decays_when_stale():
    """A DC drop storm drives drop_offset up fast; epochs that stop
    playing the DC tier decay it back toward identity (re-exploration)."""
    loop = CalibrationLoop(["svc"])
    loop.observe(0, {"svc": {"tier": "dc", "lat_s": 1.0}},
                 {"svc": {"lat_mean_s": float("nan"), "completed": 0,
                          "dropped": 10, "inflight": 0, "vos": 0.0}})
    d0 = loop.correction("svc").dc.drop_offset
    assert d0 > 0.5
    # service now plays (and observes) the edge tier only
    for k in range(1, 8):
        loop.observe(k, {"svc": {"tier": "edge", "lat_s": 1.0}},
                     {"svc": {"lat_mean_s": 1.0, "completed": 5,
                              "dropped": 0, "inflight": 0, "vos": 1.0}})
    assert loop.correction("svc").dc.drop_offset < d0
    assert loop.correction("svc").dc.drop_offset == 0.0  # under deadband


def test_correction_latency_map_and_tiers():
    c = ServiceCorrection(q_mult=2.0, lat_bias_s=1.0, drop_offset=0.25)
    assert c.latency(3.0) == 7.0
    assert c.latency(-10.0) == 0.0          # clamped at zero
    assert c.keep_prob == 0.75
    assert c.tier(True) is c and c.tier(False) is c   # flat: both tiers
    cal = ServiceCalibration(edge=ServiceCorrection(q_mult=1.5), dc=c)
    assert cal.tier(True).q_mult == 1.5
    assert cal.tier(False) is c
    d = cal.to_dict()
    assert set(d) == {"edge", "dc"}
    assert set(d["dc"]) == {"q_mult", "lat_bias_s", "drop_offset"}


# ----------------------------------------------------- forecast injection
def _mini_engine(horizon=900.0, epoch_s=300.0):
    def build():
        b = Broker()
        pipe = Pipeline(b)
        pipe.add_farm(DriftingFarm(b, step_bursts(2.0, 10.0,
                                                  [(300.0, 600.0)]),
                                   n_things=4, seed=3))
        agg = StreamService(ServiceConfig(
            name="agg", queue="neubotspeed", column="download_speed",
            agg="max", window=WindowSpec("sliding", 120.0, 30.0)), b)
        smooth = StreamService(ServiceConfig(
            name="smooth", queue="agg_out", column="value", agg="mean",
            window=WindowSpec("sliding", 120.0, 60.0)), b)
        pipe.add_service(agg).add_service(smooth)
        pipe.connect(agg, "agg_out")
        return pipe
    profiles = {"agg": ServiceProfile(_SLO, flops_per_record=2e3),
                "smooth": ServiceProfile(_SLO, flops_per_record=2e3)}
    fleet = FleetSpec(sites=(
        SiteSpec("gw-a", EdgeSpec(name="gw-a"), LinkSpec(),
                 farm_queues=("neubotspeed",)),
        SiteSpec("gw-b", EdgeSpec(name="gw-b", flops_per_s=10e9,
                                  throughput_rps=800.0),
                 LinkSpec(uplink_bps=10e6))))
    cfg = EngineConfig(fleet=fleet, horizon_s=horizon, epoch_s=epoch_s)
    return ScenarioEngine(build, profiles, cfg)


NAMES = ["agg", "smooth"]


def test_forecast_model_corrections_change_ranking_per_tier():
    """A large DC drop_offset must tax DC placements (only) in the
    forecast score; identity corrections reproduce the raw score
    bit-for-bit."""
    cs = _mini_engine()
    info = cs.info()
    rates = {"agg": 8.0, "smooth": 0.03}
    raw = ForecastModel(info, rates)
    ident = ForecastModel(info, rates,
                          corrections={s: ServiceCalibration()
                                       for s in NAMES})
    taxed = ForecastModel(info, rates, corrections={
        "agg": ServiceCalibration(dc=ServiceCorrection(drop_offset=0.9)),
        "smooth": ServiceCalibration()})
    edge = PlacementPlan.all_edge(NAMES, site="gw-a")
    dc = PlacementPlan.all_dc(NAMES, chips=4)
    assert ident.run(edge).vos == raw.run(edge).vos
    assert ident.run(dc).vos == raw.run(dc).vos
    assert taxed.run(edge).vos == raw.run(edge).vos      # edge untouched
    assert taxed.run(dc).vos < raw.run(dc).vos           # DC taxed
    res, detail = taxed.predict(dc)
    assert set(detail) == set(NAMES)
    assert detail["agg"]["tier"] == "dc"
    assert detail["agg"]["vos_raw"] > detail["agg"]["vos"]
    assert res.vos == pytest.approx(sum(d["vos"] for d in detail.values()))


def test_screening_model_corrections_and_search_threading():
    """score_batch applies tier-resolved corrections; screened_search
    installs them for the search and restores the screener's previous
    state afterwards."""
    cs = _mini_engine()
    screener = cs.screening_model()
    edge = PlacementPlan.all_edge(NAMES, site="gw-a")
    dc = PlacementPlan.all_dc(NAMES, chips=4)
    base = screener.score_batch([edge, dc])
    corr = {"agg": ServiceCalibration(dc=ServiceCorrection(drop_offset=0.9)),
            "smooth": ServiceCalibration()}
    prev = screener.set_corrections(corr)
    assert prev == {}
    taxed = screener.score_batch([edge, dc])
    assert taxed[0] == base[0]                      # edge plan untouched
    assert taxed[1] < base[1]                       # DC plan taxed
    screener.set_corrections(prev)
    assert (screener.score_batch([edge, dc]) == base).all()

    ev = Evaluator(cs)
    sr = search_placement(cs, chips_options=(4,), evaluator=ev,
                          edge_sites=("gw-a", "gw-b"), corrections=corr)
    assert sr.screen is not None and sr.screen["calibrated"] is True
    assert screener._corr == {}                     # restored after search
    sr2 = search_placement(cs, chips_options=(4,), evaluator=ev,
                           edge_sites=("gw-a", "gw-b"))
    assert sr2.screen["calibrated"] is False
    # tier 2 is exact DES either way: both searches return DES-verified
    # plans, and the calibrated tier-1 cannot make the result *worse*
    # than the anchors
    assert sr.result.vos >= min(ev(PlacementPlan.all_edge(NAMES,
                                                          site="gw-a")).vos,
                                ev(PlacementPlan.all_dc(NAMES,
                                                        chips=4)).vos)


# -------------------------------------------------- engine realized window
class _Recorder(StaticController):
    def __init__(self, plan):
        super().__init__(plan, label="rec")
        self.obs = []

    def decide(self, obs):
        self.obs.append(obs)
        return self.plan


def test_engine_realized_window_residuals():
    """Every epoch boundary exposes per-service realized residuals for
    all completed epochs: counts partition the epoch's fires, VoS and
    mean latency come from settled fires only."""
    cs = _mini_engine()
    ctrl = _Recorder(PlacementPlan.all_edge(NAMES, site="gw-a"))
    res = cs.run(ctrl)
    assert [len(o.realized_window) for o in ctrl.obs] == [0, 1, 2]
    for o in ctrl.obs:
        for per in o.realized_window:
            assert set(per) == set(NAMES)
            for svc, d in per.items():
                assert set(d) == {"vos", "completed", "dropped", "inflight",
                                  "lat_mean_s"}
                assert d["completed"] >= 0 and d["dropped"] >= 0
                if d["completed"]:
                    assert math.isfinite(d["lat_mean_s"])
                else:
                    assert d["vos"] == 0.0
    # all-edge 3-epoch run: epoch 0 fires are settled by the epoch-1
    # boundary, and their realized VoS matches the final epoch meta
    e0 = ctrl.obs[1].realized_window[0]
    assert sum(d["vos"] for d in e0.values()) == pytest.approx(
        res.summary()["epochs"][0]["vos"], abs=1e-3)


# ----------------------------------------------------------- determinism
def test_calibration_loop_determinism():
    """Same spec + seed -> bit-identical correction history and VoS
    across two fresh engines (the golden determinism regression)."""
    def run():
        spec = (scenario("det")
                .horizon(900.0).epochs(300.0)
                .farm(n_things=4, seed=3,
                      rate=RateSpec.bursts(2.0, 10.0, [(300.0, 600.0)]))
                .service("agg", queue="neubotspeed",
                         column="download_speed", agg="max",
                         width_s=120, slide_s=30)
                .slo(soft_latency_s=2.0, hard_latency_s=10.0,
                     soft_energy_j=0.3, hard_energy_j=3.0)
                .profile(flops_per_record=2e3)
                .build())
        cs = spec.compile()
        ctrl = OnlineController(chips_options=(4,), window=1,
                                switch_margin=0.02, seed=0,
                                prior_rates={"agg": 8.0}, calibrate=True)
        res = cs.run(ctrl)
        return res.vos, ctrl.calibration.history, ctrl.telemetry

    v1, h1, t1 = run()
    v2, h2, t2 = run()
    assert v1 == v2
    assert h1 == h2
    assert t1 == t2
    assert len(h1) >= 1           # the loop actually observed epochs


def test_calibrated_controller_label_and_reset():
    ctrl = OnlineController(calibrate=True)
    assert ctrl.label == "online-cal"
    assert OnlineController().label == "online"
    loop = CalibrationLoop(["agg"])
    loop.observe(0, {"agg": {"tier": "edge", "lat_s": 1.0}},
                 {"agg": {"lat_mean_s": 9.0, "completed": 3, "dropped": 0,
                          "inflight": 0, "vos": 0.0}})
    assert loop.observations == 1
    ctrl2 = OnlineController(calibration=loop)
    assert ctrl2.calibrate and ctrl2.label == "online-cal"
    cs = _mini_engine()
    ctrl2.bind(cs.info())          # bind marks a run start: loop resets
    assert loop.observations == 0 and loop.history == []


# ------------------------------------------------------ signed search regret
def _obs(epoch, rates, down=False):
    d = {"gw-a": down, "gw-b": down}
    return EpochObservation(epoch=epoch, t0=epoch * 300.0,
                            t1=(epoch + 1) * 300.0,
                            rates_window=[dict(rates)] if rates else [],
                            down_now=d, rates_oracle={}, down_oracle=d)


def test_search_regret_records_both_signs():
    """The telemetry keeps the *signed* forecast regret: zero when the
    searched best is adopted, positive when hysteresis keeps a
    worse-scoring incumbent, and negative when the searched space no
    longer contains the incumbent and its best scores below it. (Both
    gateways are reported down, so only DC plans are feasible; in this
    fabric the forecast scores dc[4] above dc[8].)"""
    cs = _mini_engine()
    info = cs.info()
    rates = {"agg": 8.0, "smooth": 0.03}

    ctrl = OnlineController(chips_options=(4,), window=1,
                            switch_margin=10.0, seed=0, prior_rates=rates)
    ctrl.bind(info)
    plan0 = ctrl.decide(_obs(0, None, down=True))   # adopt: regret == 0
    assert all(not p.is_edge and p.chips == 4
               for p in plan0.assignments.values())
    assert ctrl.telemetry[-1]["search_regret"] == 0.0
    assert ctrl.telemetry[-1]["switched"]

    # widen to chips=8 only: the best reachable plan (dc[8]) scores
    # BELOW the kept dc[4] incumbent -> negative regret, recorded signed
    ctrl.chips_options = (8,)
    ctrl.decide(_obs(1, rates, down=True))
    e1 = ctrl.telemetry[-1]
    assert not e1["switched"]
    assert e1["best_vos"] < e1["chosen_vos"]
    assert e1["search_regret"] < 0.0
    assert e1["search_regret"] == pytest.approx(
        e1["best_vos"] - e1["chosen_vos"], abs=2e-4)

    # the mirror image: a dc[8] incumbent, search re-widened to chips=4
    # finds a better plan but the huge switch margin keeps the
    # incumbent -> positive regret
    ctrl2 = OnlineController(chips_options=(8,), window=1,
                             switch_margin=10.0, seed=0, prior_rates=rates)
    ctrl2.bind(info)
    plan0 = ctrl2.decide(_obs(0, None, down=True))
    assert all(not p.is_edge and p.chips == 8
               for p in plan0.assignments.values())
    ctrl2.chips_options = (4,)
    ctrl2.decide(_obs(1, rates, down=True))
    e1 = ctrl2.telemetry[-1]
    assert not e1["switched"]
    assert e1["best_vos"] > e1["chosen_vos"]
    assert e1["search_regret"] > 0.0
    assert e1["search_regret"] == pytest.approx(
        e1["best_vos"] - e1["chosen_vos"], abs=2e-4)


# --------------------------------------------------- golden report schema
_FORECAST_KEYS = {"epoch", "best_vos", "chosen_vos", "search_regret",
                  "switched", "search", "cosim_vos", "calibration_gap"}
_SEARCH_KEYS = {"method", "evaluations", "cache_hits", "cache_misses"}
_REGRET_KEYS = {"epochs_with_telemetry", "mean_search_regret",
                "mean_calibration_gap"}
_CAL_KEYS = {"mean_abs_gap_raw", "mean_abs_gap_calibrated",
             "oracle_regret_raw", "oracle_regret_calibrated",
             "gap_shrinks", "regret_shrinks"}
_ACC_KEYS = {"online_beats_best_static", "within_10pct_of_oracle",
             "ledger_conserved", "per_site_ledger_exact", "deterministic",
             "calibration_gap_shrinks", "calibration_regret_shrinks"}
_CORR_KEYS = {"q_mult", "lat_bias_s", "drop_offset"}


def test_bench_online_report_schema_golden():
    """Golden regression for the BENCH_online.json telemetry schema:
    report consumers key on these exact field names — renaming or
    dropping any of them must fail loudly here, not silently downstream."""
    path = os.path.join(_ROOT, "BENCH_online.json")
    if not os.path.exists(path):
        pytest.skip("BENCH_online.json not generated")
    with open(path) as f:
        report = json.load(f)
    assert {"smoke", "scenarios", "acceptance"} <= set(report)
    assert {"beats_best_static", "within_oracle", "calibration_improves",
            "of", "pass"} <= set(report["acceptance"])
    assert report["acceptance"]["pass"] is True
    assert len(report["scenarios"]) >= 5      # 3 recorded + 2 drift (ISSUE 5)
    assert {"correlated_bursts", "ramp_outage"} <= set(report["scenarios"])
    for name, sc in report["scenarios"].items():
        assert {"spec", "statics", "best_static", "online",
                "online_calibrated", "oracle", "search_stats",
                "forecast_regret", "forecast_regret_calibrated",
                "calibration", "acceptance"} <= set(sc), name
        assert _REGRET_KEYS == set(sc["forecast_regret"])
        assert _REGRET_KEYS == set(sc["forecast_regret_calibrated"])
        assert _CAL_KEYS == set(sc["calibration"])
        assert _ACC_KEYS == set(sc["acceptance"])
        assert {"epochs", "evaluations", "cache_hits",
                "cache_misses"} == set(sc["search_stats"])
        for arm, extra in (("online", set()),
                           ("online_calibrated",
                            {"chosen_vos_raw", "calibration_gap_raw",
                             "corrections"})):
            for e in sc[arm]["epochs"]:
                fc = e.get("forecast")
                assert fc is not None, (name, arm, e["epoch"])
                assert _FORECAST_KEYS <= set(fc)
                assert _SEARCH_KEYS == set(fc["search"])
                assert extra <= set(fc), (name, arm, e["epoch"])
                for tiers in fc.get("corrections", {}).values():
                    assert set(tiers) == {"edge", "dc"}
                    for c in tiers.values():
                        assert set(c) == _CORR_KEYS
        # both per-scenario calibration gates held when this report
        # was generated (ISSUE 5 acceptance)
        assert sc["calibration"]["gap_shrinks"] is True, name
        assert sc["calibration"]["regret_shrinks"] is True, name
