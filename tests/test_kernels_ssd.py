"""SSD-scan kernel: sweep vs the sequential-recurrence oracle, and the
model's chunked-jnp path vs the same oracle (two independent
implementations of state-space duality must agree)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan import ssd_scan, ssd_scan_reference
from repro.models.ssm import ssd_chunked

SWEEP = [
    # (B, L, H, P, G, N, chunk, dtype, rtol)
    (2, 256, 4, 64, 1, 128, 128, jnp.float32, 1e-4),
    (1, 512, 2, 32, 1, 64, 128, jnp.float32, 1e-4),
    (2, 200, 4, 16, 2, 32, 64, jnp.float32, 1e-4),   # pad + groups
    (1, 128, 8, 64, 1, 128, 32, jnp.float32, 1e-4),
    (1, 256, 4, 64, 1, 128, 128, jnp.bfloat16, 1e-1),
]


def _inputs(B, L, H, P, G, N, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, L, H, P)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B_ = (jax.random.normal(ks[3], (B, L, G, N)) * 0.3).astype(dtype)
    C = (jax.random.normal(ks[4], (B, L, G, N)) * 0.3).astype(dtype)
    return x, dt, A, B_, C


@pytest.mark.parametrize("B,L,H,P,G,N,chunk,dtype,rtol", SWEEP)
def test_ssd_kernel_vs_sequential(B, L, H, P, G, N, chunk, dtype, rtol):
    x, dt, A, B_, C = _inputs(B, L, H, P, G, N, dtype)
    out = ssd_scan(x, dt, A, B_, C, chunk=chunk, interpret=True)
    ref = ssd_scan_reference(x, dt, A, B_, C)
    scale = float(jnp.max(jnp.abs(ref)))
    np.testing.assert_allclose(np.asarray(out, np.float32) / scale,
                               np.asarray(ref, np.float32) / scale,
                               atol=rtol)


@pytest.mark.parametrize("chunk", [32, 64, 256])
def test_model_chunked_path_vs_sequential(chunk):
    x, dt, A, B_, C = _inputs(2, 256, 4, 32, 1, 64, jnp.float32)
    y, _ = ssd_chunked(x, dt, A, B_, C, chunk=chunk)
    ref = ssd_scan_reference(x, dt, A, B_, C)
    scale = float(jnp.max(jnp.abs(ref)))
    np.testing.assert_allclose(np.asarray(y) / scale,
                               np.asarray(ref) / scale, atol=1e-4)


def test_ssd_state_handoff():
    """Chunked scan's final state equals the sequential recurrence state
    (the decode-path contract)."""
    x, dt, A, B_, C = _inputs(1, 128, 2, 16, 1, 32, jnp.float32)
    _, h_chunked = ssd_chunked(x, dt, A, B_, C, chunk=32)
    # sequential state
    from repro.models.ssm import ssd_chunked as _  # noqa
    Bh = jnp.repeat(B_, 2, axis=2)
    h = jnp.zeros((1, 2, 16, 32))
    for t in range(128):
        decay = jnp.exp(dt[:, t] * A)[..., None, None]
        dBx = (dt[:, t][..., None, None] * Bh[:, t][:, :, None, :]
               * x[:, t][..., None])
        h = h * decay + dBx
    np.testing.assert_allclose(np.asarray(h_chunked), np.asarray(h),
                               atol=1e-4, rtol=1e-3)
