"""Chaos & migration realism: withdrawable FIFO admissions, step-keyed
failure injection, restart determinism, partition-vs-outage telemetry,
ledger-mode accounting, and chaos-disabled bit-identity."""
import json

import pytest

from repro.chaos import (ChaosController, ChaosSpec, ChaosTimeline,
                         LinkStraggle, Partition, SiteCrash)
from repro.checkpoint import (CheckpointManager, FailureInjector,
                              run_with_restarts)
from repro.online import StaticController
from repro.online.fleet import LinkQueue
from repro.placement import PlacementPlan
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.scenario import RateSpec, ScenarioSpec, scenario


# ---------------------------------------------------------------------------
# LinkQueue withdraw: exact FIFO restoration
# ---------------------------------------------------------------------------
def test_linkqueue_withdraw_exact_restore():
    q = LinkQueue()
    q.admit(0.0, 2.0)
    tok = q.last_token
    q.admit(1.0, 3.0)           # queues behind the first: waits 1 s
    assert q.busy_until == 5.0 and q.queue_wait_s == 1.0 and q.transfers == 2
    assert q.withdraw(tok)
    # exactly as if only the second admission ever happened
    assert q.busy_until == 4.0 and q.queue_wait_s == 0.0 and q.transfers == 1
    assert not q.withdraw(tok)          # idempotent: already withdrawn

    fresh = LinkQueue()
    fresh.admit(1.0, 3.0)
    assert (q.busy_until, q.queue_wait_s, q.transfers) == \
        (fresh.busy_until, fresh.queue_wait_s, fresh.transfers)


def test_linkqueue_withdraw_last_skips_withdrawn():
    q = LinkQueue()
    q.admit(0.0, 1.0)
    q.admit(0.0, 1.0)
    assert q.withdraw_last()            # withdraws the second
    assert q.withdraw_last()            # then the first
    assert not q.withdraw_last()        # nothing active left
    assert q.busy_until == 0.0 and q.transfers == 0


# ---------------------------------------------------------------------------
# ChaosSpec: round-trip + validation
# ---------------------------------------------------------------------------
def test_chaos_spec_roundtrip():
    spec = ChaosSpec(
        crashes=(SiteCrash(site="gw-a", at_s=100.0, recover_s=400.0),),
        partitions=(Partition(site="gw-b", at_s=50.0, heal_s=200.0),),
        straggles=(LinkStraggle(site="gw-a", at_s=500.0, until_s=700.0,
                                factor=4.0),),
        migration="live", ledger_mode="at_least_once",
        checkpoint_every=8, p_crash=0.01, seed=7)
    d = json.loads(json.dumps(spec.to_dict()))
    assert ChaosSpec.from_dict(d) == spec


@pytest.mark.parametrize("bad", [
    dict(migration="teleport"),
    dict(ledger_mode="maybe_once"),
    dict(crashes=(SiteCrash(site="nope", at_s=0.0, recover_s=1.0),)),
    dict(crashes=(SiteCrash(site="gw-a", at_s=5.0, recover_s=5.0),)),
    dict(straggles=(LinkStraggle(site="gw-a", at_s=0.0, until_s=1.0,
                                 factor=0.5),)),
])
def test_chaos_spec_validation_rejects(bad):
    with pytest.raises(ValueError):
        ChaosSpec(**bad).validate(["gw-a", "gw-b"])


# ---------------------------------------------------------------------------
# FailureInjector: step-keyed, replay-stable, fire-once
# ---------------------------------------------------------------------------
def test_failure_injector_step_keyed():
    a = FailureInjector(p_fail=0.3, seed=42)
    b = FailureInjector(p_fail=0.3, seed=42)
    # a consumes draws out of order; b in order — step-keyed draws make
    # consumption order irrelevant (the old stateful-RNG bug made a
    # restart replay probe DIFFERENT coins than the uninterrupted run)
    order_a = [5, 1, 3, 0, 2, 4]
    fired_a = {s for s in order_a if a.should_fail(s)}
    fired_b = {s for s in range(6) if b.should_fail(s)}
    assert fired_a == fired_b == set(a.fail_times(6)) == set(b.fail_times(6))
    # fire-once: a replayed step succeeds (the node was replaced)
    for s in fired_a:
        assert not a.should_fail(s)
    # fail_times is pure: consuming draws doesn't change it
    assert a.fail_times(6) == FailureInjector(p_fail=0.3, seed=42).fail_times(6)


def test_chaos_timeline_random_crashes_deterministic():
    spec = ChaosSpec(p_crash=0.5, seed=3)
    epochs = [(0.0, 300.0), (300.0, 600.0), (600.0, 900.0)]
    t1 = ChaosTimeline.compile(spec, ["gw-a", "gw-b"], 900.0, epochs)
    t2 = ChaosTimeline.compile(spec, ["gw-a", "gw-b"], 900.0, epochs)
    for s in ("gw-a", "gw-b"):
        assert t1.crash_windows(s) == t2.crash_windows(s)
    assert t1.any_faults()      # p=0.5 over 6 coins: seed 3 fires


# ---------------------------------------------------------------------------
# run_with_restarts: history regression + determinism under failure
# ---------------------------------------------------------------------------
def _toy_runner():
    import jax.numpy as jnp

    def one_step(state, step):
        return ({"w": state["w"] + jnp.float32(step + 1)},
                {"w0": float(state["w"])})
    return one_step


def test_restart_history_strictly_increasing(tmp_path):
    """Regression: a restart used to leave already-replayed steps in the
    history, duplicating entries. History must match the uninterrupted
    run exactly."""
    import jax.numpy as jnp
    init = {"w": jnp.float32(0.0)}
    mgr = CheckpointManager(str(tmp_path / "a"), save_every=3,
                            async_write=False)
    _, hist, restarts = run_with_restarts(
        init_state=init, train_one_step=_toy_runner(), ckpt_manager=mgr,
        n_steps=9, injector=FailureInjector(fail_steps=[4, 7]))
    assert restarts == 2
    steps = [s for s, _ in hist]
    assert steps == list(range(9))      # no duplicates, no gaps
    mgr_c = CheckpointManager(str(tmp_path / "c"), save_every=3,
                              async_write=False)
    _, hist_clean, _ = run_with_restarts(
        init_state=init, train_one_step=_toy_runner(), ckpt_manager=mgr_c,
        n_steps=9, injector=FailureInjector())
    assert hist == hist_clean


def test_restart_under_failure_deterministic(tmp_path):
    """Same seed -> bit-identical history and final state across two
    independent runs through random injected failures."""
    import jax.numpy as jnp
    init = {"w": jnp.float32(0.0)}
    results = []
    for tag in ("a", "b"):
        mgr = CheckpointManager(str(tmp_path / tag), save_every=2,
                                async_write=False)
        s, h, r = run_with_restarts(
            init_state=init, train_one_step=_toy_runner(), ckpt_manager=mgr,
            n_steps=12, injector=FailureInjector(p_fail=0.25, seed=9))
        results.append((float(s["w"]), h, r))
    assert results[0] == results[1]
    assert results[0][2] > 0            # the schedule actually fired
    assert float(results[0][0]) == sum(range(1, 13))


def test_ckpt_manager_owns_executor(tmp_path):
    """Regression: the async writer used to be a module-level default-arg
    ThreadPoolExecutor shared by every manager and never shut down."""
    m1 = CheckpointManager(str(tmp_path / "1"), save_every=1)
    m2 = CheckpointManager(str(tmp_path / "2"), save_every=1)
    m1.maybe_save(1, {"w": 1.0})
    m2.maybe_save(1, {"w": 2.0})
    assert m1._executor is not None and m2._executor is not None
    assert m1._executor is not m2._executor
    m1.finalize()
    assert m1._executor is None         # shut down and released
    assert m2._executor is not None     # m2 unaffected
    m2.finalize()


# ---------------------------------------------------------------------------
# Engine integration: shared tiny scenario
# ---------------------------------------------------------------------------
def _mini_spec(chaos=None, outage=None) -> ScenarioSpec:
    b = (scenario("chaos_mini")
         .site("gw-a", edge=EdgeSpec(name="gw-a", throughput_rps=2000.0,
                                     active_power_w=1.0,
                                     energy_per_record_j=50e-6),
               link=LinkSpec(uplink_bps=15e3, downlink_bps=2e6, rtt_s=0.040,
                             record_bytes=64.0, compression=0.25))
         .site("gw-b", edge=EdgeSpec(name="gw-b", throughput_rps=1500.0,
                                     flops_per_s=15e9, active_power_w=1.2,
                                     energy_per_record_j=60e-6),
               link=LinkSpec(uplink_bps=12e3, downlink_bps=2e6, rtt_s=0.060,
                             record_bytes=64.0, compression=0.25))
         .horizon(1200.0).epochs(300.0).dc(dc_step_floor_s=2e-3)
         .farm(n_things=6, seed=11, site="gw-a",
               rate=RateSpec.constant(4.0)))
    (b.service("agg", queue="neubotspeed", column="download_speed",
               agg="max", width_s=120, slide_s=30, buffer_budget=8192)
     .slo(soft_latency_s=2.0, hard_latency_s=10.0,
          soft_energy_j=0.3, hard_energy_j=3.0)
     .profile(flops_per_record=2e3))
    if outage is not None:
        b.outage("gw-a", *outage)
    if chaos is not None:
        b.chaos(chaos)
    return b.build()


def _static_a():
    return StaticController(PlacementPlan.all_edge(["agg"], site="gw-a"),
                            label="static:pin-a")


def _chaos_ctrl(seed=0):
    return ChaosController(chips_options=(4,), window=1, switch_margin=0.02,
                           seed=seed, prior_rates={"agg": 8.0})


def test_chaos_disabled_bit_identity():
    """A spec without chaos and the same engine with every chaos code
    path dormant must produce the identical result."""
    r0 = _mini_spec().compile().run(_static_a())
    r1 = _mini_spec(chaos=None).compile().run(_static_a())
    assert r0.vos == r1.vos
    assert r0.ledger.totals() == r1.ledger.totals()
    assert r0.summary()["epochs"] == r1.summary()["epochs"]
    assert "duplicates" not in r0.ledger.totals()


def test_partition_is_not_outage():
    """A partition downs the link, not the device: down_now stays False,
    partitioned_now flips, and local edge work still completes. The
    oracle (down_oracle) stays blind to chaos — it reads only the
    scheduled outage windows."""
    ch = ChaosSpec(partitions=(Partition(site="gw-a", at_s=350.0,
                                         heal_s=850.0),))
    cs = _mini_spec(chaos=ch).compile()
    seen = {}

    class Probe(StaticController):
        def decide(self, obs):
            seen[obs.epoch] = (dict(obs.down_now), dict(obs.partitioned_now),
                               dict(obs.down_oracle))
            return super().decide(obs)

    r = cs.run(Probe(PlacementPlan.all_edge(["agg"], site="gw-a"),
                     label="static:pin-a"))
    down, part, oracle = seen[2]        # t0=600: mid-partition
    assert part["gw-a"] and not down["gw-a"]
    assert not oracle["gw-a"]           # planning stays blind to chaos
    # device alive: the all-local plan kept processing through it
    assert r.ledger.conserved()
    assert r.ledger.totals()["processed_edge"] > 0
    # scheduled outage, by contrast, is oracle-visible AND downs the device
    cs2 = _mini_spec(outage=(350.0, 850.0)).compile()
    seen.clear()
    cs2.run(Probe(PlacementPlan.all_edge(["agg"], site="gw-a"),
                  label="static:pin-a"))
    down2, part2, oracle2 = seen[2]
    assert down2["gw-a"] and oracle2["gw-a"] and not part2["gw-a"]


def test_crash_telemetry_realized_only():
    """An unplanned crash surfaces in down_now once it fires — never in
    down_oracle."""
    ch = ChaosSpec(crashes=(SiteCrash(site="gw-a", at_s=350.0,
                                      recover_s=850.0),))
    cs = _mini_spec(chaos=ch).compile()
    seen = {}

    class Probe(StaticController):
        def decide(self, obs):
            seen[obs.epoch] = (dict(obs.down_now), dict(obs.down_oracle))
            return super().decide(obs)

    cs.run(Probe(PlacementPlan.all_edge(["agg"], site="gw-b"),
                 label="static:pin-b"))
    assert seen[2][0]["gw-a"] and not seen[2][1]["gw-a"]
    assert not seen[0][0]["gw-a"]       # nothing before onset


def _crash_spec(mode):
    return ChaosSpec(
        crashes=(SiteCrash(site="gw-a", at_s=350.0, recover_s=1000.0),),
        migration="cold", ledger_mode=mode)


def test_ledger_exactly_once():
    """Exactly-once draining: conservation holds and nothing is
    double-processed (no duplicates key in the totals)."""
    cs = _mini_spec(chaos=_crash_spec("exactly_once")).compile()
    r = cs.run(_chaos_ctrl())
    assert r.summary()["epochs"][1].get("chaos"), "no mid-epoch re-plan fired"
    assert r.ledger.conserved()
    assert "duplicates" not in r.ledger.totals()


def test_ledger_at_least_once_duplicates_accounted():
    """At-least-once cutover: every replayed record is double-processed
    and every one of them is accounted — duplicates == the replay counts
    the migrations declared, and conservation still holds (duplicates
    sit outside the partition by design)."""
    cs = _mini_spec(chaos=_crash_spec("at_least_once")).compile()
    r = cs.run(_chaos_ctrl())
    replans = [e for ep in r.summary()["epochs"]
               for e in ep.get("chaos", ())]
    declared = sum(m["replay_records"] for e in replans
                   for m in e["migrations"] if m["duplicates"])
    assert declared > 0
    assert r.ledger.totals()["duplicates"] == declared
    assert r.ledger.conserved()


def test_chaos_run_deterministic():
    """Two same-seed runs under chaos are bit-identical: vos, ledger,
    and the full epoch meta (including migration digests)."""
    ra = _mini_spec(chaos=_crash_spec("exactly_once")).compile() \
        .run(_chaos_ctrl(seed=5))
    rb = _mini_spec(chaos=_crash_spec("exactly_once")).compile() \
        .run(_chaos_ctrl(seed=5))
    assert ra.vos == rb.vos
    assert ra.ledger.totals() == rb.ledger.totals()
    assert ra.summary()["epochs"] == rb.summary()["epochs"]


def test_straggle_slows_but_conserves():
    """A straggling uplink inflates transfer serialization (visible in
    link_secs_window) without losing records."""
    ch = ChaosSpec(straggles=(LinkStraggle(site="gw-a", at_s=300.0,
                                           until_s=900.0, factor=6.0),))
    cs = _mini_spec(chaos=ch).compile()
    seen = {}

    class Probe(StaticController):
        def decide(self, obs):
            seen[obs.epoch] = [dict(w) for w in obs.link_secs_window]
            return super().decide(obs)

    r = cs.run(Probe(PlacementPlan.all_dc(["agg"], chips=4),
                     label="static:dc"))
    assert r.ledger.conserved()
    windows = seen[max(seen)]
    quiet, slow = windows[0]["gw-a"], windows[1]["gw-a"]
    assert quiet > 0 and slow > quiet * 3   # factor-6 straggle visible
