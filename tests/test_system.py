"""End-to-end behaviour tests: training learns, serving is coherent,
fault-tolerant training resumes exactly, the VoS scheduler plans real jobs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data import ShardedLoader
from repro.launch.train import train_loop
from repro.models import model as M
from repro.train import TrainHParams
from repro.train.serve_step import greedy_generate


def test_training_learns_markov_structure():
    """Loss on the synthetic Markov stream must drop materially."""
    _, losses = train_loop("smollm-135m", steps=120, batch=8, seq=64,
                           log_every=10**9,
                           hp=TrainHParams(peak_lr=3e-3, warmup_steps=10,
                                           total_steps=120, grad_accum=1,
                                           remat="none"))
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    assert last < first - 0.5, (first, last)


def test_training_with_restarts_matches_uninterrupted(tmp_path):
    common = dict(steps=30, batch=4, seq=32, save_every=10, seed=7,
                  log_every=10**9)
    s1, l1 = train_loop("qwen3-1.7b", ckpt_dir=str(tmp_path / "a"),
                        p_fail=0.0, **common)
    s2, l2 = train_loop("qwen3-1.7b", ckpt_dir=str(tmp_path / "b"),
                        p_fail=0.08, **common)
    # final params identical: restart replays the same step-keyed batches
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_greedy_generation_deterministic():
    cfg = get_arch("mamba2-1.3b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32)[None].repeat(2, 0)}
    t1, _ = greedy_generate(cfg, params, batch, steps=8, cache_len=48)
    t2, _ = greedy_generate(cfg, params, batch, steps=8, cache_len=48)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert t1.shape == (2, 8)


def test_scheduler_plans_and_jobs_run():
    """Integration: VoS plan → real (reduced) training jobs execute."""
    from repro.core.costmodel import CostModel
    from repro.core.heuristics import HEURISTICS
    from repro.core.simulator import Simulator
    from repro.core.tasks import PAPER_REGIME, TaskType, WorkloadGenerator
    cost = CostModel.analytic()
    types = [TaskType("smollm-135m", "train_4k")]
    gen = WorkloadGenerator(types, cost, seed=0, **PAPER_REGIME)
    trace = gen.trace(4)
    res = Simulator(HEURISTICS["VPTR"], cost).run(trace)
    assert res.completed >= 3
    ran = [t for t in res.tasks if t.start is not None][:1]
    for t in ran:
        _, losses = train_loop(t.ttype.arch, steps=3, batch=2, seq=32,
                               log_every=10**9)
        assert np.isfinite(losses[-1])
