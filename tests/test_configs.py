import pytest

from repro.configs import SHAPES, get_arch, list_archs, supports_shape

EXPECTED_PARAMS = {  # advertised sizes, total params (tolerance: ±35%)
    "smollm-135m": 135e6,
    "qwen3-1.7b": 1.7e9,
    "yi-6b": 6e9,
    "qwen3-14b": 14e9,
    "olmoe-1b-7b": 7e9,
    "jamba-v0.1-52b": 52e9,
    "internvl2-76b": 76e9,   # assigned cell is the LM backbone
    "mamba2-1.3b": 1.3e9,
    "whisper-medium": 769e6,
}


def test_ten_archs_registered():
    assert len(list_archs()) == 10


@pytest.mark.parametrize("name", list_archs())
def test_param_counts_match_advertised(name):
    cfg = get_arch(name)
    counts = cfg.param_counts()
    assert counts["active"] <= counts["total"]
    if name in EXPECTED_PARAMS:
        exp = EXPECTED_PARAMS[name]
        assert 0.65 * exp < counts["total"] < 1.45 * exp, (
            f"{name}: {counts['total']:.2e} vs advertised {exp:.2e}")


@pytest.mark.parametrize("name", list_archs())
def test_scan_groups_reconstruct_layers(name):
    cfg = get_arch(name)
    pattern, repeat = cfg.scan_groups()
    assert len(pattern) * repeat == cfg.n_layers
    assert pattern * repeat == cfg.layer_kinds()


@pytest.mark.parametrize("name", list_archs())
def test_padded_vocab(name):
    cfg = get_arch(name)
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= cfg.vocab_size
    assert cfg.padded_vocab - cfg.vocab_size < 256


def test_long_context_gating():
    long = SHAPES["long_500k"]
    ok = {a for a in list_archs() if supports_shape(get_arch(a), long)[0]}
    assert ok == {"jamba-v0.1-52b", "mamba2-1.3b"}
    for a in list_archs():
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert supports_shape(get_arch(a), SHAPES[s])[0]


@pytest.mark.parametrize("name", list_archs())
def test_reduced_configs_are_small(name):
    cfg = get_arch(name).reduced()
    assert cfg.param_counts()["total"] < 20e6
    assert cfg.scan_groups()[0] == get_arch(name).scan_groups()[0]  # pattern kept
