"""Property tests on the VDC buddy allocator (composable submeshes)."""
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't crash collection
from hypothesis import given, settings, strategies as st

from repro.core.vdc import PodGrid


def _no_overlap(grid):
    cells = set()
    for v in grid.used.values():
        for x in range(v.tile.x, v.tile.x + v.tile.w):
            for y in range(v.tile.y, v.tile.y + v.tile.h):
                assert (x, y) not in cells
                cells.add((x, y))
    return cells


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from([4, 8, 16, 32, 64, 128, 256]),
                min_size=1, max_size=30),
       st.integers(0, 2**31 - 1))
def test_alloc_free_invariants(sizes, seed):
    import random
    rng = random.Random(seed)
    grid = PodGrid()
    live = []
    for s in sizes:
        v = grid.compose(s, 1.0, task_id=0)
        if v is not None:
            live.append(v)
            assert v.chips == s
        cells = _no_overlap(grid)
        assert len(cells) == grid.used_chips
        assert grid.used_chips + grid.free_chips == 256
        if live and rng.random() < 0.4:
            grid.release(live.pop(rng.randrange(len(live))))
    for v in live:
        grid.release(v)
    assert grid.free_chips == 256
    # coalescing must restore a full-grid allocation
    assert grid.compose(256, 1.0, 0) is not None


def test_full_then_none():
    grid = PodGrid()
    assert grid.compose(256, 1.0, 0) is not None
    assert grid.compose(4, 1.0, 1) is None


def test_non_power_of_two_rejected():
    with pytest.raises(ValueError):
        PodGrid().compose(24, 1.0, 0)
