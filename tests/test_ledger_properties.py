"""Property-based conservation tests for the shared record ledger
(repro.scenario.ledger): for random small specs and placement plans,
records in == records out + drops + in-flight at *every* cut of the
pipeline — per service (broker -> fetch -> coverage partition) and per
site (the processed roll-up partitions across gateways + DC)."""
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't crash collection
from hypothesis import given, settings, strategies as st

from repro.placement import PlacementPlan, ServicePlacement
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.scenario import RateSpec, scenario

_SLO_KW = dict(soft_latency_s=2.0, hard_latency_s=10.0,
               soft_energy_j=0.5, hard_energy_j=10.0)

_WINDOWS = [(60.0, 30.0), (120.0, 60.0), (90.0, 45.0)]


@st.composite
def _case(draw):
    """A small random scenario spec + a random placement plan over it."""
    n_sites = draw(st.integers(1, 2))
    sites = ["gw-a", "gw-b"][:n_sites]
    shared = draw(st.booleans())        # second service shares the queue
    chain = draw(st.booleans())         # add a downstream consumer
    rate = draw(st.sampled_from([1.0, 2.5, 4.0]))
    bursty = draw(st.booleans())
    n_things = draw(st.integers(1, 3))
    budgets = draw(st.lists(st.sampled_from([64, 256, 4096]),
                            min_size=3, max_size=3))
    widths = [draw(st.sampled_from(_WINDOWS)) for _ in range(3)]
    store_on = draw(st.booleans())
    seed = draw(st.integers(0, 10))

    b = scenario("ledger-prop").horizon(180.0)
    for s in sites:
        b.site(s, edge=EdgeSpec(name=s),
               link=LinkSpec(uplink_bps=2e5, record_bytes=128.0))
    r = (RateSpec.bursts(rate, rate * 4.0, [(60.0, 120.0)]) if bursty
         else RateSpec.constant(rate))
    b.farm(n_things=n_things, seed=seed, rate=r, site=sites[0])

    names = ["svc0"]
    (b.service("svc0", queue="neubotspeed", column="download_speed",
               agg="max", width_s=widths[0][0], slide_s=widths[0][1],
               buffer_budget=budgets[0])
     .slo(**_SLO_KW).profile(flops_per_record=2e3))
    if store_on:
        b.with_store(chunk_seconds=60.0, edge_budget_chunks=2)
    if shared:
        names.append("svc1")
        (b.service("svc1", queue="neubotspeed", column="latency_ms",
                   agg="mean", width_s=widths[1][0], slide_s=widths[1][1],
                   buffer_budget=budgets[1])
         .slo(**_SLO_KW).profile(flops_per_record=2e3))
    if chain:
        names.append("tail")
        (b.service("tail", queue="svc0_out", column="value", agg="mean",
                   width_s=widths[2][0], slide_s=widths[2][1],
                   buffer_budget=budgets[2])
         .fed_by("svc0")
         .slo(**_SLO_KW).profile(flops_per_record=2e3))
    spec = b.build()

    options = [ServicePlacement(s) for s in sites]
    options.append(ServicePlacement("dc", chips=4))
    plan = PlacementPlan({n: draw(st.sampled_from(options)) for n in names})
    return spec, plan


@settings(max_examples=15, deadline=None)
@given(case=_case())
def test_ledger_conserves_at_every_cut(case):
    from repro.online import StaticController
    spec, plan = case
    res = spec.compile().run(StaticController(plan))
    ledger = res.ledger
    assert ledger.conserved()
    for name, sl in ledger.services.items():
        # cut 1: the broker queue — everything published either
        # overflowed, is still unread, or was fetched
        assert sl.produced == sl.overflow + sl.unread + sl.fetched, name
        # cut 2: the service buffer — everything fetched is covered by
        # a fire, still buffered, or was evicted (spilled or lost)
        assert sl.fetched == (sl.covered + sl.buffered + sl.evicted_stored
                              + sl.evicted_lost), name
        # cut 3: fire outcomes partition the covered records
        assert sl.covered == (sl.processed_edge + sl.processed_dc
                              + sl.dropped_dc + sl.inflight_dc), name
        # derived buckets stay consistent with the partition
        assert sl.dropped == sl.overflow + sl.dropped_dc + sl.evicted_lost
        assert sl.in_flight == (sl.unread + sl.buffered + sl.inflight_dc
                                + sl.evicted_stored)
        for k in ("produced", "overflow", "unread", "fetched",
                  "processed_edge", "processed_dc", "dropped_dc",
                  "inflight_dc", "buffered", "evicted_stored",
                  "evicted_lost"):
            assert getattr(sl, k) >= 0, (name, k)

    # per-site cut: the processed roll-up partitions exactly across
    # gateways + DC — no record is attributed to two sites or none
    tot = ledger.totals()
    site_sum = sum(d.get("records_processed", 0)
                   for d in res.per_site.values())
    assert site_sum == tot["processed_edge"] + tot["processed_dc"]
    # fires partition too
    assert res.fires_total == (res.fires_completed + res.fires_dropped
                               + res.fires_inflight)


@settings(max_examples=8, deadline=None)
@given(case=_case())
def test_ledger_deterministic_across_runs(case):
    """One spec + plan -> bit-identical ledgers on fresh engines."""
    spec, plan = case
    t1 = spec.compile().run_plan(plan).ledger.totals()
    t2 = spec.compile().run_plan(plan).ledger.totals()
    assert t1 == t2
