"""Window-aggregation kernel: sweep + hypothesis property vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't crash collection
from hypothesis import given, settings, strategies as st

from repro.kernels.window_agg import (window_aggregate,
                                      window_aggregate_reference)

SWEEP = [
    (600, 5, 180, 60, "max", jnp.float32),
    (600, 5, 180, 60, "mean", jnp.float32),
    (1024, 130, 256, 64, "sum", jnp.float32),
    (777, 3, 120, 40, "min", jnp.float32),
    (2000, 1, 500, 100, "mean", jnp.float32),
    (512, 128, 128, 128, "max", jnp.bfloat16),
]


@pytest.mark.parametrize("T,C,w,s,agg,dtype", SWEEP)
def test_window_vs_ref(T, C, w, s, agg, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), (T, C)) * 10).astype(dtype)
    out = window_aggregate(x, agg=agg, window=w, stride=s, interpret=True)
    ref = window_aggregate_reference(x, agg=agg, window=w, stride=s)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 8), st.integers(1, 12), st.integers(1, 6),
       st.sampled_from(["max", "min", "sum", "mean"]),
       st.integers(0, 2**31 - 1))
def test_window_property(m, n_windows, stride_u, agg, seed):
    """For random (window = m·stride), the kernel equals the oracle."""
    stride = stride_u * 17          # non-power-of-two strides
    window = m * stride
    T = window + (n_windows - 1) * stride
    x = np.random.default_rng(seed).standard_normal((T, 3)).astype(np.float32)
    out = window_aggregate(jnp.asarray(x), agg=agg, window=window,
                           stride=stride, interpret=True)
    ref = window_aggregate_reference(jnp.asarray(x), agg=agg, window=window,
                                     stride=stride)
    assert out.shape == (n_windows, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_window_rejects_nonmultiple():
    x = jnp.zeros((100, 1))
    with pytest.raises(ValueError):
        window_aggregate(x, agg="max", window=50, stride=33, interpret=True)
