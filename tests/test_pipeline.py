"""DS pipeline services: broker semantics, store scans, the Neubot
queries vs a numpy oracle, and the edge→VDC offload decision."""
import numpy as np
import pytest

from repro.pipeline import (Broker, HybridExecutor, NeubotFarm, Pipeline,
                            TimeSeriesStore, neubot_query_1)
from repro.pipeline.operators import WindowSpec, kmeans, linear_regression
from repro.pipeline.service import ServiceConfig, StreamService
from repro.pipeline.streams import Record


def test_queue_offsets_and_bounds():
    b = Broker()
    q = b.queue("q", capacity=10)
    q.register("c1")
    for i in range(15):
        q.publish(Record(ts=float(i), values={"v": float(i)}))
    got = q.fetch("c1")
    assert q.dropped == 5
    assert [r.values["v"] for r in got] == list(range(5, 15))
    assert q.fetch("c1") == []  # offset advanced


def test_store_scan_matches_appended():
    s = TimeSeriesStore("t", chunk_seconds=10.0, edge_budget_chunks=2)
    for i in range(100):
        s.append(Record(ts=float(i), values={"v": float(i)}))
    s.flush()
    vals = s.scan(25.0, 75.0, "v")
    np.testing.assert_array_equal(vals, np.arange(25.0, 75.0))
    assert s.spill_events > 0           # budget forced spills
    assert s.resident_chunks <= 3       # budget + open chunk slack


def test_q1_windowed_max_vs_oracle():
    broker = Broker()
    store = TimeSeriesStore("speed", chunk_seconds=600)
    farm = NeubotFarm(broker, n_things=3, rate_hz=1.0, seed=1)
    q1 = neubot_query_1(broker, store)
    pipe = Pipeline(broker).add_farm(farm).add_service(q1)
    res = pipe.advance_to(600.0)["q1_max_speed"]
    assert len(res) == 10  # every 60 s
    # oracle: regenerate the same records
    farm2 = NeubotFarm(Broker(), n_things=3, rate_hz=1.0, seed=1)
    q = farm2.producers[0].q
    farm2.advance_to(600.0)
    recs = list(q.buf)
    for r in res:
        now = r["ts"]
        vals = [x.values["download_speed"] for x in recs
                if now - 180.0 <= x.ts < now]
        assert abs(r["value"] - max(vals)) < 1e-6


def test_service_buffer_eviction_spills_to_store():
    broker = Broker()
    store = TimeSeriesStore("s", chunk_seconds=100)
    svc = StreamService(ServiceConfig(
        name="tiny", queue="q", column="v", agg="mean",
        window=WindowSpec("sliding", 50.0, 10.0), buffer_budget=16,
        store=store), broker)
    q = broker.queue("q")
    for i in range(200):
        q.publish(Record(ts=float(i), values={"v": 1.0}))
    svc.run_until(200.0)
    assert svc.buffer_evictions > 0
    assert len(svc.buffer) <= 16 + 1


def test_fetch_spill_accounting_is_exact():
    """Fetch's data-management strategy, pinned record by record: stale
    records (older than the window) spill first, then budget overflow
    evicts the oldest in-window records; every eviction increments
    ``buffer_evictions`` exactly once and lands in the store."""
    broker = Broker()
    store = TimeSeriesStore("s", chunk_seconds=1000.0)
    svc = StreamService(ServiceConfig(
        name="tiny", queue="q", column="v", agg="sum",
        window=WindowSpec("sliding", 50.0, 10.0), buffer_budget=16,
        store=store), broker)
    q = broker.queue("q")
    for i in range(100):                       # ts 0..99, one record each
        q.publish(Record(ts=float(i), values={"v": float(i)}))
    n = svc.fetch()
    assert n == 100
    # horizon = 99 - 50 = 49 → 49 stale (ts 0..48); 51 in-window > 16
    # budget → 35 more evicted (ts 49..83); buffer keeps ts 84..99
    assert svc.buffer_evictions == 49 + 35
    assert [r.ts for r in svc.buffer] == [float(i) for i in range(84, 100)]
    store.flush()
    spilled = store.scan(0.0, 84.0, "v")
    assert len(spilled) == 84                  # all evictions retained
    np.testing.assert_array_equal(np.sort(spilled), np.arange(84.0))
    # the operator can still see spilled history through the store
    res = svc.fire(100.0)
    assert res["n"] == 50                      # window [50, 100): 34+16


def test_fetch_eviction_without_store_loses_records():
    """Same pressure, no store: the counter still counts, the records
    are gone (the co-sim ledgers classify these as evicted_lost)."""
    broker = Broker()
    svc = StreamService(ServiceConfig(
        name="lossy", queue="q", column="v", agg="count",
        window=WindowSpec("sliding", 50.0, 10.0), buffer_budget=16), broker)
    q = broker.queue("q")
    for i in range(100):
        q.publish(Record(ts=float(i), values={"v": 1.0}))
    svc.fetch()
    assert svc.buffer_evictions == 84
    assert len(svc.buffer) == 16
    res = svc.fire(100.0)
    assert res["n"] == 16                      # only the buffer survives


def test_buffer_evictions_counter_accumulates_across_fetches():
    """Incremental fetches: the counter is monotone and equals the total
    number of records ever removed from the buffer, not a per-fetch
    snapshot; in-window records under budget are never evicted."""
    broker = Broker()
    svc = StreamService(ServiceConfig(
        name="inc", queue="q", column="v", agg="mean",
        window=WindowSpec("sliding", 1000.0, 10.0), buffer_budget=8), broker)
    q = broker.queue("q")
    for i in range(8):                         # fits: no evictions
        q.publish(Record(ts=float(i), values={"v": 1.0}))
    svc.fetch()
    assert svc.buffer_evictions == 0 and len(svc.buffer) == 8
    for i in range(8, 12):                     # 4 over budget
        q.publish(Record(ts=float(i), values={"v": 1.0}))
    svc.fetch()
    assert svc.buffer_evictions == 4
    for i in range(12, 14):                    # 2 more
        q.publish(Record(ts=float(i), values={"v": 1.0}))
    svc.fetch()
    assert svc.buffer_evictions == 6
    assert [r.ts for r in svc.buffer] == [float(i) for i in range(6, 14)]


def test_offload_decision_boundary():
    hx = HybridExecutor(edge_budget=1000)
    assert not hx.decide(1000).offload
    assert hx.decide(1001).offload
    big = np.random.default_rng(0).standard_normal(4096).astype(np.float32)
    v = hx.run_window(big, "max")
    assert abs(v - big.max()) < 1e-5
    assert hx.offloads == 1


def test_kmeans_and_linreg_services():
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    xs = np.concatenate([rng.normal(0, .5, (50, 2)),
                         rng.normal(5, .5, (50, 2))])
    centers, assign = kmeans(jnp.asarray(xs, jnp.float32), k=2, iters=25)
    d = abs(float(centers[0, 0]) - float(centers[1, 0]))
    assert d > 3.0  # separated the clusters
    x = jnp.linspace(0, 1, 100)
    y = 2.0 + 3.0 * x
    beta, resid = linear_regression(x, y)
    np.testing.assert_allclose(np.asarray(beta), [2.0, 3.0], atol=1e-4)


def test_cnn_classifier_service():
    """The paper's CNN analytics operator: a tiny conv net separates
    synthetic 'stable' from 'bursty' connectivity windows after a few
    gradient steps (trained as any analytics service would be)."""
    import jax
    import jax.numpy as jnp
    from repro.pipeline.operators import cnn_classify, init_cnn_classifier

    rng = np.random.default_rng(0)
    stable = rng.normal(1.0, 0.05, (64, 64)).astype(np.float32)
    bursty = (rng.normal(1.0, 0.05, (64, 64))
              + (rng.random((64, 64)) < 0.15) * rng.normal(4, 1, (64, 64))
              ).astype(np.float32)
    x = jnp.asarray(np.concatenate([stable, bursty]))
    y = jnp.asarray([0] * 64 + [1] * 64)

    params = init_cnn_classifier(jax.random.PRNGKey(0), n_classes=2)

    def loss(p):
        logits = cnn_classify(p, x)
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(128), y])

    g = jax.jit(jax.grad(loss))
    for _ in range(60):
        grads = g(params)
        params = jax.tree.map(lambda p, gr: p - 0.3 * gr, params, grads)
    acc = float(jnp.mean(jnp.argmax(cnn_classify(params, x), -1) == y))
    assert acc > 0.9, acc
