"""Elastic reallocation policies: plan_regrow (grow a running job onto a
larger free tile when the recovered value beats the migration pause) and
plan_replacement (diff two placement plans into service migrations)."""
import pytest

from repro.core.costmodel import CellCost, CostModel
from repro.core.elastic import (MIGRATION_OVERHEAD_S, SERVICE_WARMUP_S,
                                ServiceMigration, plan_regrow,
                                plan_replacement)
from repro.core.tasks import Task, TaskType
from repro.core.value import TaskValueSpec, ValueCurve
from repro.core.vdc import PodGrid


# --------------------------------------------------------------- fixtures
def _cost(t_compute=1.0):
    # step_time(chips) = t_compute * 256/chips (compute-bound cell)
    return CostModel({("a", "s"): CellCost(t_compute, 1e-3, 1e-3, 1e9)})


def _spec(soft, hard):
    curve = ValueCurve(1.0, 0.1, soft, hard)
    return TaskValueSpec(gamma=1.0, w_p=0.7, w_e=0.3, perf_curve=curve,
                         energy_curve=ValueCurve(1.0, 0.1, 1e12, 1e13))


def _running_task(cost, chips=16, steps=10, soft=100.0, hard=300.0):
    """A task mid-flight on a `chips` VDC, started at t=0."""
    task = Task(tid=0, ttype=TaskType("a", "s", allowable_chips=(16, 64)),
                steps=steps, arrival=0.0, value=_spec(soft, hard))
    grid = PodGrid()                      # 256 chips: room to grow
    vdc = grid.compose(chips, 1.0, task.tid)
    t_step = cost.time_per_step("a", "s", chips, 1.0)
    task.start, task.finish = 0.0, t_step * steps
    task.chips = chips
    return task, vdc, grid


# ------------------------------------------------------------ plan_regrow
def test_regrow_proposes_profitable_grow():
    """16→64 chips cuts the remaining 10 steps from 16 s to 4 s each;
    even after the 30 s migration pause the job finishes far earlier and
    recovers latency value."""
    cost = _cost()
    task, vdc, grid = _running_task(cost, soft=100.0, hard=300.0)
    mig = plan_regrow([(task, vdc)], grid, cost, now=10.0)
    assert mig is not None
    assert mig.old_chips == 16 and mig.new_chips == 64
    assert mig.gain > 0
    # the gain must equal the value delta its own cost math implies
    t_old = cost.time_per_step("a", "s", 16, 1.0)
    t_new = cost.time_per_step("a", "s", 64, 1.0)
    done_frac = 10.0 / (task.finish - task.start)
    steps_left = max(1, int(10 * (1 - done_frac)))
    finish_old = 10.0 + steps_left * t_old
    finish_new = 10.0 + MIGRATION_OVERHEAD_S + steps_left * t_new
    assert finish_new < finish_old        # sanity: grow really is faster

    def val(latency):
        return task.value.gamma * (
            0.7 * task.value.perf_curve.value(latency)
            + 0.3 * task.value.energy_curve.value(task.energy_j))
    assert mig.gain == pytest.approx(val(finish_new) - val(finish_old),
                                     abs=1e-6)


def test_regrow_none_without_free_chips():
    """A fully occupied grid cannot host a larger tile."""
    cost = _cost()
    task = Task(tid=0, ttype=TaskType("a", "s", allowable_chips=(16, 64)),
                steps=10, arrival=0.0, value=_spec(100.0, 300.0))
    grid = PodGrid(4, 4)                  # 16 chips total, all taken
    vdc = grid.compose(16, 1.0, task.tid)
    t_step = cost.time_per_step("a", "s", 16, 1.0)
    task.start, task.finish = 0.0, t_step * 10
    assert grid.free_chips == 0
    assert plan_regrow([(task, vdc)], grid, cost, now=10.0) is None


def test_regrow_none_when_not_worth_the_pause():
    """If the job already earns max value (soft threshold far away), the
    30 s pause cannot recover anything — no migration."""
    cost = _cost()
    task, vdc, grid = _running_task(cost, soft=1e6, hard=2e6)
    assert plan_regrow([(task, vdc)], grid, cost, now=10.0) is None


def test_regrow_respects_allowable_chips():
    """Chips outside the task's allowable set are never proposed."""
    cost = _cost()
    task, vdc, grid = _running_task(cost)
    task.ttype = TaskType("a", "s", allowable_chips=(16,))  # nothing larger
    assert plan_regrow([(task, vdc)], grid, cost, now=10.0) is None


def test_regrow_picks_best_gain_among_tasks():
    cost = _cost()
    t1, v1, grid = _running_task(cost, soft=100.0, hard=300.0)
    t2 = Task(tid=1, ttype=TaskType("a", "s", allowable_chips=(16, 64)),
              steps=10, arrival=0.0, value=_spec(1e6, 2e6))  # already max
    v2 = grid.compose(16, 1.0, t2.tid)
    t_step = cost.time_per_step("a", "s", 16, 1.0)
    t2.start, t2.finish = 0.0, t_step * 10
    mig = plan_regrow([(t1, v1), (t2, v2)], grid, cost, now=10.0)
    assert mig is not None and mig.task is t1


# ------------------------------------------------------ plan_replacement
class _P:
    def __init__(self, site):
        self.site = site


def test_plan_replacement_diffs_site_moves_only():
    old = {"a": _P("gw-1"), "b": _P("dc"), "c": _P("gw-1")}
    new = {"a": _P("gw-2"), "b": _P("dc"), "c": _P("gw-1")}
    migs = plan_replacement(old, new,
                            state_bytes_fn=lambda s: 1000.0,
                            transfer_time_fn=lambda src, dst, b: b / 500.0)
    assert [m.service for m in migs] == ["a"]
    m = migs[0]
    assert (m.src, m.dst) == ("gw-1", "gw-2")
    assert m.transfer_s == pytest.approx(2.0)
    assert m.stall_s == pytest.approx(2.0 + SERVICE_WARMUP_S)


def test_plan_replacement_new_service_and_no_moves():
    old = {"a": _P("gw-1")}
    new = {"a": _P("gw-1"), "b": _P("dc")}   # b has no old placement
    migs = plan_replacement(old, new, lambda s: 1.0, lambda *a: 0.0)
    assert migs == []
    assert isinstance(ServiceMigration("x", "a", "b", 1.0, 0.5).stall_s,
                      float)
