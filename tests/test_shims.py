"""Deprecation shims: warn loudly, delegate bit-identically.

``repro.placement.cosim.CoSimulator`` and ``repro.online.des_bridge``
are scheduled for removal in v0.9 (2026-12-01; see README, Migration
table). Until then they must (a) emit a ``DeprecationWarning`` at their
legacy entry points, (b) delegate to the unified engine with
bit-identical results, and (c) never tax the *non*-deprecated names —
the observation-protocol types now live in ``repro.scenario.observe``
and importing them through ``repro.online`` stays warning-free."""
import importlib
import sys
import warnings

import pytest

from repro.scenario import RateSpec, scenario

_SLO_KW = dict(soft_latency_s=2.0, hard_latency_s=10.0,
               soft_energy_j=2.0, hard_energy_j=100.0)


def _spec(horizon: float = 240.0):
    return (scenario("shim")
            .horizon(horizon)
            .farm(n_things=3, seed=2, rate=RateSpec.constant(1.5))
            .service("agg", queue="neubotspeed", column="download_speed",
                     agg="max", width_s=120, slide_s=60)
            .slo(**_SLO_KW).profile(flops_per_record=2e3)
            .build())


# ---------------------------------------------------- CoSimulator shim
def test_cosimulator_init_emits_deprecation_warning():
    from repro.placement import CoSimConfig, CoSimulator
    spec = _spec()
    with pytest.warns(DeprecationWarning, match="CoSimulator is deprecated"):
        CoSimulator(spec.build_pipeline, spec.profiles(),
                    CoSimConfig(horizon_s=240.0))


def test_cosimulator_delegates_bit_identically():
    """The shim's run() must be the unified engine's run_plan() — same
    VoS, same ledger, same per-service detail, not approximately."""
    from repro.placement import CoSimConfig, CoSimulator, PlacementPlan
    spec = _spec()
    plan = PlacementPlan.all_edge(spec.service_names())
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        shim = CoSimulator(spec.build_pipeline, spec.profiles(),
                           CoSimConfig(horizon_s=240.0))
    legacy = shim.run(plan)
    unified = spec.compile().run_plan(plan)
    assert legacy.vos == unified.vos
    assert legacy.ledger == unified.ledger
    assert legacy.per_service == unified.per_service


def test_cosimulator_import_alone_does_not_warn():
    """Importing the shim *module* (e.g. for its re-exported ledger
    names) must stay silent; only instantiating the legacy class pays."""
    sys.modules.pop("repro.placement.cosim", None)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        import repro.placement.cosim  # noqa: F401


# ----------------------------------------------------- des_bridge shim
def test_des_bridge_import_emits_deprecation_warning():
    sys.modules.pop("repro.online.des_bridge", None)
    with pytest.warns(DeprecationWarning, match="des_bridge is deprecated"):
        importlib.import_module("repro.online.des_bridge")


def test_des_bridge_aliases_are_the_engine():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.online.des_bridge import (FleetCoSimulator, OnlineConfig,
                                             OnlineResult)
    from repro.scenario.engine import (EngineConfig, EngineResult,
                                       ScenarioEngine)
    assert FleetCoSimulator is ScenarioEngine
    assert OnlineConfig is EngineConfig
    assert OnlineResult is EngineResult


def test_observation_names_via_online_stay_warning_free():
    """BridgeInfo/EpochObservation/ServiceInfo moved to
    repro.scenario.observe; resolving them through ``repro.online`` must
    not route through (or import) the deprecated shim."""
    sys.modules.pop("repro.online.des_bridge", None)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        from repro.online import (BridgeInfo, EpochObservation,  # noqa: F401
                                  ServiceInfo)
    assert "repro.online.des_bridge" not in sys.modules
    from repro.scenario import observe
    from repro.online import BridgeInfo as B2
    assert B2 is observe.BridgeInfo
