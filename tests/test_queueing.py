"""The shared queueing-inflation helper (repro.scenario.queueing): the
scalar, vectorized-numpy, and jnp variants must be the SAME function —
bit-equal on every input — and the legacy import sites (screen's
``q_factor``/``_q_factor``, the forecast model) must resolve to it."""
import numpy as np
import pytest

from repro.scenario.queueing import (NEVER_S, Q_CLIFF, Q_KNEE, q_factor,
                                     q_factor_np)


def test_knee_semantics():
    assert q_factor(0.0) == 1.0
    assert q_factor(Q_KNEE) == 1.0
    assert q_factor(Q_CLIFF) == NEVER_S
    assert q_factor(2.0) == NEVER_S
    u = 0.9
    assert q_factor(u) == 1.0 + (u - Q_KNEE) / (Q_CLIFF - u)
    assert q_factor(0.8) > 1.0


def test_scalar_equals_numpy():
    """Scalar and vectorized variants are bit-equal in float64 (the
    precision the screen and forecast model run at)."""
    u = np.concatenate([np.linspace(0.0, 1.2, 241),
                        [Q_KNEE, Q_CLIFF, 0.9499999, 0.9500001]])
    vec = q_factor_np(u)
    scal = np.array([q_factor(float(x)) for x in u])
    assert (vec == scal).all()


def test_polymorphic_dispatch():
    """q_factor accepts arrays and matches the vectorized variant."""
    u = np.linspace(0.0, 1.1, 45)
    assert (q_factor(u) == q_factor_np(u)).all()


def test_jnp_equals_numpy_float32():
    """The jnp variant (the fluid engine runs float32) is bit-equal to
    the numpy variant evaluated at the same float32 precision."""
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.scenario.queueing import q_factor_jnp
    u = np.linspace(0.0, 1.2, 121, dtype=np.float32)
    j = np.asarray(q_factor_jnp(jnp.asarray(u)))
    vec = q_factor_np(u).astype(np.float32)
    assert j.dtype == np.float32
    assert np.allclose(j, vec, rtol=2e-7, atol=0.0)
    # exact in the flat regions; the mid-curve ratio may differ by the
    # f32-vs-f64 rounding of a single divide, never more than 1 ULP
    flat = (u <= Q_KNEE) | (u >= Q_CLIFF)
    assert (j[flat] == vec[flat]).all()
    ulp = np.spacing(np.maximum(np.abs(j), np.abs(vec)))
    assert (np.abs(j - vec) <= ulp).all()


def test_legacy_import_sites_share_the_helper():
    from repro.scenario import screen
    from repro.online import controller
    assert screen.q_factor is q_factor
    assert screen._q_factor is q_factor_np
    assert controller.q_factor is q_factor
    assert screen.NEVER_S == NEVER_S


@pytest.mark.parametrize("seed", range(5))
def test_property_scalar_vec_jnp_agree(seed):
    """Random inputs: scalar == numpy bit-equal in float64; jnp within
    1 float32 ULP of the numpy variant at float32."""
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.scenario.queueing import q_factor_jnp
    rng = np.random.default_rng(seed)
    u = rng.uniform(0.0, 1.5, size=64)
    scal = np.array([q_factor(float(x)) for x in u])
    vec = q_factor_np(u)
    assert (vec == scal).all()
    u32 = u.astype(np.float32)
    j = np.asarray(q_factor_jnp(jnp.asarray(u32)))
    vec32 = q_factor_np(u32).astype(np.float32)
    ulp = np.spacing(np.maximum(np.abs(j), np.abs(vec32)))
    assert (np.abs(j - vec32) <= ulp).all()
