"""Unified Scenario API: builder → spec → JSON round-trip → compile →
unified DES-bridged engine; kernel calibration of flops_per_record; the
deprecated CoSimulator shim delegating to the engine; and the
equivalence regression pinning the engine against the recorded
BENCH_placement.json results (searched ≥ baselines must hold 3/3)."""
import dataclasses
import json
import os

import pytest

from repro.placement import (CoSimConfig, CoSimulator, PlacementPlan,
                             ServicePlacement)
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.scenario import (KernelCalibrator, RateSpec, ScenarioSpec,
                            ServiceSLO, scenario)

_SLO_KW = dict(soft_latency_s=2.0, hard_latency_s=10.0,
               soft_energy_j=2.0, hard_energy_j=100.0)


def _mini_spec(horizon: float = 300.0) -> ScenarioSpec:
    return (scenario("mini")
            .horizon(horizon)
            .farm(n_things=4, seed=3, rate=RateSpec.constant(2.0))
            .service("agg", queue="neubotspeed", column="download_speed",
                     agg="max", width_s=120, slide_s=30)
            .slo(**_SLO_KW).profile(flops_per_record=2e3)
            .service("smooth", queue="agg_out", column="value", agg="mean",
                     width_s=120, slide_s=60)
            .fed_by("agg")
            .slo(**_SLO_KW).profile(flops_per_record=2e3)
            .build())


def _rich_spec() -> ScenarioSpec:
    """Exercises every declarative dimension: multi-site fleet, pinned
    farms, drift kinds, outages, stores, epochs, DC knobs."""
    return (scenario("rich")
            .horizon(1200.0).epochs(300.0)
            .dc(records_per_step=2000, dc_step_floor_s=2e-3)
            .site("gw-a", edge=EdgeSpec(name="gw-a", active_power_w=4.0),
                  link=LinkSpec(uplink_bps=1e6), user=True)
            .site("gw-b")
            .outage("gw-b", 300.0, 600.0)
            .farm(queue="neubotspeed", n_things=3, seed=7, site="gw-a",
                  rate=RateSpec.diurnal(2.0, amplitude=0.5, period_s=1200.0))
            .farm(queue="aux", n_things=2, seed=9, site="gw-b",
                  rate=RateSpec.piecewise([(0.0, 1.0), (600.0, 4.0),
                                           (1200.0, 1.0)]))
            .service("a", queue="neubotspeed", column="download_speed",
                     agg="max", width_s=120, slide_s=60)
            .slo(**_SLO_KW).profile(flops_per_record=3e3)
            .with_store(chunk_seconds=600.0, edge_budget_chunks=4)
            .service("b", queue="aux", column="latency_ms", agg="mean",
                     width_s=120, slide_s=60)
            .slo(**_SLO_KW).profile(flops_per_record=3e3)
            .service("fuse", queue="mix", column="value", agg="mean",
                     width_s=240, slide_s=120)
            .fed_by("a", "b")
            .slo(**_SLO_KW).profile(flops_per_record=3e3)
            .build())


# ---------------------------------------------------------------- builder
def test_builder_topology_and_profiles():
    spec = _mini_spec()
    assert spec.service_names() == ["agg", "smooth"]
    assert spec.topology() == {"agg": [], "smooth": ["agg"]}
    profs = spec.profiles()
    assert profs["agg"].flops_per_record == 2e3
    assert profs["agg"].slo.soft_latency_s == 2.0
    rich = _rich_spec()
    assert rich.topology() == {"a": [], "b": [], "fuse": ["a", "b"]}
    assert {s.name for s in rich.sites} == {"gw-a", "gw-b"}
    assert rich.sites[0].farm_queues == ("neubotspeed",)
    assert rich.user_site == "gw-a"
    assert rich.outage_map() == {"gw-b": ((300.0, 600.0),)}


def test_builder_rejects_bad_wiring():
    with pytest.raises(ValueError, match="consumes"):
        (scenario("dangling")
         .farm().service("x", queue="nobody_publishes_this").build())
    with pytest.raises(ValueError, match="duplicate"):
        (scenario("dup").farm()
         .service("x", queue="neubotspeed")
         .service("x", queue="neubotspeed").build())
    with pytest.raises(ValueError, match="fed_by unknown"):
        (scenario("bad").farm()
         .service("x", queue="neubotspeed")
         .service("y", queue="q2").fed_by("ghost").build())
    with pytest.raises(ValueError, match="reserved"):
        scenario("dcsite").site("dc")


# ------------------------------------------------------------- round-trip
def test_json_roundtrip_mini_and_rich():
    for spec in (_mini_spec(), _rich_spec()):
        back = ScenarioSpec.from_json(spec.to_json())
        assert back == spec
        # and a second trip is stable (canonical form)
        assert back.to_json() == spec.to_json()


def test_rate_spec_curves_match_drift_generators():
    from repro.online import diurnal, piecewise_linear, step_bursts

    h = 600.0
    pairs = [
        (RateSpec.diurnal(4.0, amplitude=0.5, period_s=100.0, phase_s=25.0),
         diurnal(4.0, amplitude=0.5, period_s=100.0, phase_s=25.0)),
        (RateSpec.bursts(1.0, 5.0, [(10.0, 20.0)]),
         step_bursts(1.0, 5.0, [(10.0, 20.0)])),
        (RateSpec.piecewise([(0.0, 1.0), (10.0, 3.0)]),
         piecewise_linear([(0.0, 1.0), (10.0, 3.0)])),
    ]
    for rspec, ref in pairs:
        rt = RateSpec(**json.loads(json.dumps(dataclasses.asdict(rspec))))
        for t in (0.0, 5.0, 15.0, 50.0):
            assert rspec.curve(h)(t) == pytest.approx(ref(t))
            assert rt.curve(h)(t) == pytest.approx(ref(t))


# ----------------------------------------------------------------- engine
def test_compile_run_plan_conserved_and_deterministic():
    spec = _mini_spec()
    names = spec.service_names()
    plan = PlacementPlan({"agg": ServicePlacement("edge"),
                          "smooth": ServicePlacement("dc", chips=4)})
    r1 = spec.compile().run_plan(plan)
    r2 = spec.compile().run_plan(plan)
    assert r1.feasible and r1.ledger.conserved()
    assert r1.vos == r2.vos
    assert r1.ledger.totals() == r2.ledger.totals()
    assert r1.per_service["agg"]["site"] == "edge"
    assert r1.per_service["smooth"]["site"] == "dc[4]@1"
    # all-edge and all-dc also conserve on the same engine instance
    engine = spec.compile()
    for p in (PlacementPlan.all_edge(names),
              PlacementPlan.all_dc(names, chips=4)):
        assert engine.run_plan(p).ledger.conserved()


def test_compiled_multi_site_engine_runs_controllers():
    from repro.online import StaticController

    spec = _rich_spec()
    engine = spec.compile()
    assert len(engine.epochs) == 4
    plan = PlacementPlan({"a": ServicePlacement("gw-a"),
                          "b": ServicePlacement("gw-b"),
                          "fuse": ServicePlacement("dc", chips=4)})
    res = engine.run(StaticController(plan))
    assert res.ledger.conserved()
    assert set(res.per_site) >= {"gw-a", "gw-b", "dc"}
    # outage windows reached the fleet
    assert engine.outages == {"gw-b": ((300.0, 600.0),)}


def test_cosim_shim_matches_engine():
    """The deprecated CoSimulator delegates to the unified engine: same
    build/profiles/cfg must produce bit-identical results."""
    spec = _mini_spec()
    plan = PlacementPlan({"agg": ServicePlacement("edge"),
                          "smooth": ServicePlacement("dc", chips=4)})
    via_spec = spec.compile().run_plan(plan)
    shim = CoSimulator(spec.build_pipeline, spec.profiles(),
                       CoSimConfig(horizon_s=spec.horizon_s))
    via_shim = shim.run(plan)
    assert via_shim.vos == via_spec.vos
    assert via_shim.ledger.totals() == via_spec.ledger.totals()
    assert via_shim.energy_total_j == via_spec.energy_total_j


def test_compile_requires_flops_or_calibrator():
    b = (scenario("uncal").farm(n_things=2, rate=RateSpec.constant(1.0))
         .service("x", queue="neubotspeed", column="latency_ms", agg="mean",
                  width_s=60, slide_s=30)
         .slo(**_SLO_KW).profile(flops_per_record=None))
    spec = b.build()
    with pytest.raises(ValueError, match="flops_per_record"):
        spec.compile()
    spec.compile(calibrator=lambda s: 123.0)   # any callable works


# ------------------------------------------------------------- calibration
def test_kernel_calibrator_measures_and_caches():
    cal = KernelCalibrator()
    c1 = cal.measure("window_agg", agg="max", m=2)
    c2 = cal.measure("window_agg", agg="max", m=2)
    assert c1 is c2                       # cached
    assert c1.flops_per_record > 0
    assert c1.source in ("xla-cost-analysis", "analytic")
    assert len(cal.log) == 1
    # deterministic across instances
    assert (KernelCalibrator().measure("window_agg", agg="max", m=2)
            .flops_per_record == pytest.approx(c1.flops_per_record))
    with pytest.raises(ValueError, match="unknown operator"):
        cal.measure("not_a_kernel")


def test_calibrated_compile_uses_measured_flops():
    spec = _mini_spec(horizon=120.0)
    cal = KernelCalibrator()
    engine = spec.compile(calibrator=cal)
    for name in ("agg", "smooth"):
        svc = next(s for s in spec.services if s.name == name)
        assert engine.profiles[name].flops_per_record == pytest.approx(
            cal(svc))
        assert engine.profiles[name].flops_per_record != 2e3


# ----------------------------------------------- equivalence regression
def _bench_path() -> str:
    return os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_placement.json")


@pytest.mark.skipif(not os.path.exists(_bench_path()),
                    reason="no recorded BENCH_placement.json")
def test_unified_engine_matches_recorded_placement_bench():
    """Retiring the two-pass scheme must not silently shift VoS: replay
    the recorded searched plans through the unified engine and require
    (a) the recorded VoS reproduces exactly and (b) searched ≥ both
    baselines still holds on all 3 scenarios."""
    with open(_bench_path()) as f:
        rep = json.load(f)
    assert not rep.get("smoke") and not rep.get("calibrated")
    assert len(rep["scenarios"]) == 3
    for name, sc in rep["scenarios"].items():
        spec = ScenarioSpec.from_dict(sc["spec"])
        engine = spec.compile()
        names = list(engine.topology)
        searched = engine.run_plan(
            PlacementPlan.from_dict(sc["search"]["assignments"]))
        assert searched.feasible and searched.ledger.conserved(), name
        assert searched.vos == pytest.approx(sc["searched"]["vos"],
                                             abs=1e-3), name
        chips0 = sc["search"]["chips_options"][0]
        baselines = [engine.run_plan(PlacementPlan.all_edge(names)),
                     engine.run_plan(PlacementPlan.all_dc(names,
                                                          chips=chips0))]
        base_best = max([r.vos for r in baselines if r.feasible]
                        or [float("-inf")])
        assert searched.vos >= base_best - 1e-9, name
        # the recorded baseline VoS must reproduce too (conservation of
        # the whole score surface, not just the winner)
        for key, res in (("all_edge", baselines[0]),
                         ("all_dc", baselines[1])):
            rec = sc[key]["vos"]
            if rec is None:
                assert not res.feasible, (name, key)
            else:
                assert res.vos == pytest.approx(rec, abs=1e-3), (name, key)


def test_slo_dataclass_roundtrip():
    slo = ServiceSLO(soft_latency_s=1.0, hard_latency_s=2.0, gamma=2.0,
                     w_p=0.6, shape="linear")
    assert ServiceSLO(**dataclasses.asdict(slo)) == slo


# ------------------------------------------- two-tier screened search
@pytest.mark.skipif(not os.path.exists(_bench_path()),
                    reason="no recorded BENCH_placement.json")
def test_screened_search_matches_exact_on_recorded_scenarios():
    """The fast path must not change the answer: on every recorded
    placement scenario the two-tier screened search must return the
    same best-plan VoS as the exact exhaustive/greedy search (tier-2
    re-scoring of the top-K survivors + anchors bounds any tier-1
    mis-rank)."""
    from repro.placement import Evaluator, search_placement

    with open(_bench_path()) as f:
        rep = json.load(f)
    assert len(rep["scenarios"]) == 3
    for name, sc in rep["scenarios"].items():
        spec = ScenarioSpec.from_dict(sc["spec"])
        engine = spec.compile()
        chips = tuple(sc["search"]["chips_options"])
        exact = search_placement(engine, chips_options=chips,
                                 dvfs_options=(1.0, 0.7), screen=False)
        ev = Evaluator(engine)
        screened = search_placement(engine, chips_options=chips,
                                    dvfs_options=(1.0, 0.7), evaluator=ev)
        assert screened.screen is not None, name
        assert screened.result.vos == pytest.approx(exact.result.vos,
                                                    abs=1e-9), name
        # the screened tier really did skip most of the exact work
        assert screened.evaluations < exact.evaluations, name
        assert ev.screened >= screened.screen["top_k"], name
        # and the recorded searched VoS is reproduced by the fast path
        assert screened.result.vos == pytest.approx(
            sc["searched"]["vos"], abs=1e-3), name


def test_batch_screening_deterministic_and_matches_single():
    """score_batch is pure array math: identical scores across calls
    and across fresh engines; the single-plan front agrees with the
    batched scores."""
    import numpy as np

    from repro.placement import PlacementPlan, ServicePlacement
    from repro.placement.plan import enumerate_plans

    spec = _mini_spec()
    names = spec.service_names()
    plans = list(enumerate_plans(names, (4, 8), (1.0, 0.7)))
    s1 = spec.compile().screening_model().score_batch(plans)
    s2 = spec.compile().screening_model().score_batch(plans)
    assert np.array_equal(s1, s2)
    sm = spec.compile().screening_model()
    for i in (0, 3, len(plans) - 1):
        r = sm.run(plans[i])
        assert r.vos == pytest.approx(sm.score_batch([plans[i]])[0])
    # RAM-infeasible plans screen to -inf, like the engine's run_plan
    tiny = dataclasses.replace(
        spec, sites=(dataclasses.replace(
            spec.sites[0], edge=EdgeSpec(ram_bytes=1024.0)),))
    r = tiny.compile().screening_model().run(
        PlacementPlan.all_edge(names))
    assert not r.feasible and r.vos == float("-inf")


def test_screened_search_deterministic_on_sampled_spaces():
    """Fleet-scale spaces go through seeded sampling + batched hill
    climbing: a fixed seed must reproduce the same plan, VoS and
    screening stats (a tiny enumerate_limit forces the sampled path)."""
    from repro.placement import screened_search

    spec = _rich_spec()
    spec = dataclasses.replace(spec, epoch_s=None, outages=())
    sites = tuple(s.name for s in spec.sites)
    runs = []
    for _ in range(2):
        engine = spec.compile()
        sr = screened_search(engine, chips_options=(4, 8),
                             dvfs_options=(1.0, 0.7), edge_sites=sites,
                             seed=7, enumerate_limit=8, sample_budget=64,
                             climbers=3, climb_rounds=4)
        runs.append(sr)
    a, b = runs
    assert a.method == "screened-sampled"
    assert a.plan.key() == b.plan.key()
    assert a.result.vos == b.result.vos
    screen_a = {k: v for k, v in a.screen.items() if k != "screen_wall_s"}
    screen_b = {k: v for k, v in b.screen.items() if k != "screen_wall_s"}
    assert screen_a == screen_b
