"""Online fleet controller: drift determinism, fleet routing/FIFO
contention, outage deferral, and the end-to-end FleetCoSimulator —
per-service AND per-site record conservation, controller determinism,
drift-driven migrations, incremental DES submission."""
import pytest

from repro.online import (ContendedUplink, DriftingFarm, Fleet,
                          FleetCoSimulator, FleetSpec, OnlineConfig,
                          OnlineController, OracleController, SiteSpec,
                          StaticController, constant, diurnal,
                          piecewise_linear, poisson_bursts, step_bursts)
from repro.online.fleet import EdgeSite
from repro.pipeline import (Broker, Pipeline, ServiceConfig, StreamService,
                            WindowSpec)
from repro.placement import (PlacementPlan, ServicePlacement, ServiceProfile,
                             ServiceSLO)
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec


# ------------------------------------------------------------------- drift
def test_rate_curves_shapes():
    d = diurnal(4.0, amplitude=0.5, period_s=100.0, phase_s=25.0)
    assert d(25.0) == pytest.approx(4.0)          # zero crossing
    assert d(50.0) == pytest.approx(6.0)          # peak
    assert d(0.0) == pytest.approx(2.0)           # trough
    s = step_bursts(1.0, 5.0, [(10.0, 20.0)])
    assert s(5.0) == 1.0 and s(15.0) == 5.0 and s(20.0) == 1.0
    p = piecewise_linear([(0.0, 1.0), (10.0, 3.0), (20.0, 3.0)])
    assert p(-5.0) == 1.0 and p(5.0) == pytest.approx(2.0)
    assert p(15.0) == 3.0 and p(99.0) == 3.0
    with pytest.raises(ValueError):
        diurnal(1.0, amplitude=1.5)
    with pytest.raises(ValueError):
        piecewise_linear([(0.0, 1.0)])


def test_drifting_farm_deterministic():
    def stream(seed):
        b = Broker()
        farm = DriftingFarm(b, poisson_bursts(2.0, 8.0, 300.0,
                                              mean_gap_s=60.0,
                                              mean_len_s=30.0, seed=9),
                            n_things=3, seed=seed)
        farm.advance_to(300.0)
        q = b.queue("neubotspeed")
        return [(r.ts, r.values["download_speed"]) for r in q.buf]

    assert stream(7) == stream(7)
    assert stream(7) != stream(8)


def test_drifting_rate_tracks_curve():
    b = Broker()
    farm = DriftingFarm(b, step_bursts(1.0, 10.0, [(100.0, 200.0)]),
                        n_things=1, seed=0)
    farm.advance_to(300.0)
    ts = [r.ts for r in b.queue("neubotspeed").buf]
    burst = sum(1 for t in ts if 100.0 <= t < 200.0)
    quiet = sum(1 for t in ts if t < 100.0)
    assert burst == pytest.approx(10 * quiet, rel=0.2)


# ------------------------------------------------------------------- fleet
def test_fleet_spec_validation():
    site = SiteSpec("gw", EdgeSpec())
    with pytest.raises(ValueError):               # duplicate names
        FleetSpec(sites=(site, SiteSpec("gw", EdgeSpec())))
    with pytest.raises(ValueError):               # reserved name
        FleetSpec(sites=(SiteSpec("dc", EdgeSpec()),))
    with pytest.raises(ValueError):               # farm pinned twice
        FleetSpec(sites=(SiteSpec("a", EdgeSpec(), farm_queues=("q",)),
                         SiteSpec("b", EdgeSpec(), farm_queues=("q",))))
    spec = FleetSpec(sites=(SiteSpec("a", EdgeSpec(), farm_queues=("q",)),
                            SiteSpec("b", EdgeSpec())))
    assert spec.farm_site("q") == "a"
    assert spec.farm_site("unpinned") == "a"      # defaults to first site
    assert spec.result_site == "a"


def test_contended_uplink_fifo_serializes():
    up = ContendedUplink()
    s1 = up.admit(0.0, 10.0)
    s2 = up.admit(1.0, 5.0)                       # arrives while busy
    assert (s1, s2) == (0.0, 10.0)
    assert up.queue_wait_s == pytest.approx(9.0)
    s3 = up.admit(50.0, 1.0)                      # idle pipe: immediate
    assert s3 == 50.0


def test_edge_site_outage_defers_fires():
    site = EdgeSite(SiteSpec("gw", EdgeSpec()), outages=[(100.0, 200.0)])
    assert site.failed_at(150.0) and not site.failed_at(200.0)
    ex = site.execute_fire(150.0, 10, 0.0)
    assert ex.start >= 200.0                      # deferred to recovery
    ex2 = site.execute_fire(10.0, 10, 0.0)        # device now busy past 200
    assert ex2.start >= ex.finish


def test_fleet_routing_legs():
    spec = FleetSpec(sites=(
        SiteSpec("a", EdgeSpec(), LinkSpec(uplink_bps=1e4, rtt_s=0.1,
                                           record_bytes=100.0)),
        SiteSpec("b", EdgeSpec(), LinkSpec(uplink_bps=1e4, rtt_s=0.2,
                                           record_bytes=100.0))))
    fleet = Fleet(spec)
    t = fleet.ship_records("a", "dc", 10, 0.0)    # uplink leg only
    assert t == pytest.approx(0.05 + 1000 / 1e4)
    assert fleet.sites["a"].net.bytes_up == 1000
    t2 = fleet.ship_records("a", "b", 10, 10.0)   # up + dst downlink
    assert t2 > 10.0 + 1000 / 1e4
    assert fleet.sites["b"].net.bytes_down == 1000
    assert fleet.ship_records("a", "a", 10, 5.0) == 5.0   # same-site free
    before = fleet.uplink.transfers
    fleet.ship_state("a", "b", 5000.0, 0.0)       # migrations contend too
    assert fleet.uplink.transfers == before + 1


# --------------------------------------------------------------- end-to-end
# energy budget spans the VDC floor (~1.15 J at 4 chips): the edge wins
# on energy while it can keep up, so placements have real gradients
_SLO = ServiceSLO(soft_latency_s=2.0, hard_latency_s=10.0,
                  soft_energy_j=0.3, hard_energy_j=3.0)


def _build(seed=3):
    def build():
        b = Broker()
        pipe = Pipeline(b)
        pipe.add_farm(DriftingFarm(b, step_bursts(2.0, 10.0, [(300.0, 600.0)]),
                                   n_things=4, seed=seed))
        agg = StreamService(ServiceConfig(
            name="agg", queue="neubotspeed", column="download_speed",
            agg="max", window=WindowSpec("sliding", 120.0, 30.0)), b)
        smooth = StreamService(ServiceConfig(
            name="smooth", queue="agg_out", column="value", agg="mean",
            window=WindowSpec("sliding", 120.0, 60.0)), b)
        pipe.add_service(agg).add_service(smooth)
        pipe.connect(agg, "agg_out")
        return pipe
    return build


def _fleet():
    # gw-b is a last-resort box: slow record pump, so fires stretch to
    # seconds under load — the controller has a real reason to go home
    return FleetSpec(sites=(
        SiteSpec("gw-a", EdgeSpec(name="gw-a"), LinkSpec(),
                 farm_queues=("neubotspeed",)),
        SiteSpec("gw-b", EdgeSpec(name="gw-b", flops_per_s=10e9,
                                  throughput_rps=800.0),
                 LinkSpec(uplink_bps=10e6))))


def _cosim(outages=None):
    profiles = {"agg": ServiceProfile(_SLO, flops_per_record=2e3),
                "smooth": ServiceProfile(_SLO, flops_per_record=2e3)}
    cfg = OnlineConfig(fleet=_fleet(), horizon_s=900.0, epoch_s=300.0)
    return FleetCoSimulator(_build(), profiles, cfg, outages=outages)


NAMES = ["agg", "smooth"]


@pytest.mark.parametrize("plan_fn", [
    lambda: PlacementPlan.all_edge(NAMES, site="gw-a"),
    lambda: PlacementPlan.all_dc(NAMES, chips=4),
    lambda: PlacementPlan({"agg": ServicePlacement("gw-b"),
                           "smooth": ServicePlacement("dc", chips=4)}),
])
def test_fleet_cosim_conservation(plan_fn):
    """Per-service ledgers conserve exactly and the per-site roll-up
    partitions processed records across gateways + DC."""
    cs = _cosim()
    res = cs.run(StaticController(plan_fn()))
    assert res.ledger.conserved()
    tot = res.ledger.totals()
    site_sum = sum(d.get("records_processed", 0)
                   for d in res.per_site.values())
    assert site_sum == tot["processed_edge"] + tot["processed_dc"]
    assert res.fires_total == (res.fires_completed + res.fires_dropped
                               + res.fires_inflight)
    # every fire reached a terminal state
    assert all(f.terminal for fl in cs._fires.values() for f in fl)


def test_fleet_cosim_deterministic():
    plan = PlacementPlan({"agg": ServicePlacement("gw-a"),
                          "smooth": ServicePlacement("dc", chips=4)})
    r1 = _cosim().run(StaticController(plan))
    r2 = _cosim().run(StaticController(plan))
    assert r1.vos == r2.vos
    assert r1.ledger.totals() == r2.ledger.totals()
    assert r1.energy_total_j == r2.energy_total_j


def test_cross_site_placement_pays_the_haul():
    """agg placed on gw-b while its farm is on gw-a must route every
    record across the backhaul; placed at home it ships nothing."""
    cs = _cosim()
    home = cs.run(StaticController(PlacementPlan.all_edge(NAMES,
                                                          site="gw-a")))
    away = cs.run(StaticController(PlacementPlan(
        {"agg": ServicePlacement("gw-b"),
         "smooth": ServicePlacement("gw-b")})))
    assert home.bytes_up == 0
    assert away.bytes_up > 0
    assert away.per_site["gw-b"]["records_processed"] > 0
    assert away.uplink_transfers > 0


def test_dc_tasks_submitted_incrementally():
    """DC fires enter one persistent Simulator as produced: the DES sees
    every epoch's tasks (not a one-shot trace) and its completion count
    matches the fires the bridge scored completed."""
    cs = _cosim()
    res = cs.run(StaticController(PlacementPlan.all_dc(NAMES, chips=4)))
    assert res.dc is not None
    n_tasks = res.dc.completed + res.dc.dropped
    assert n_tasks == res.fires_total            # every fire became a task
    assert res.dc.completed == res.fires_completed
    # tasks arrived across the whole horizon, not bunched at t=0
    arrivals = [t.arrival for t in res.dc.tasks]
    assert min(arrivals) < 300.0 < max(arrivals)


def _online_ctrl():
    return OnlineController(chips_options=(4,), window=1,
                            switch_margin=0.01,
                            prior_rates={"agg": 8.0, "smooth": 0.03})


def test_outage_forces_migration_and_recovery():
    """Failing the farm site mid-run makes the online controller move
    services off it (paying migration) and return after recovery."""
    outages = {"gw-a": [(300.0, 600.0)]}
    cs = _cosim(outages=outages)
    res = cs.run(_online_ctrl())
    assert res.migrations > 0
    plans = [e["plan"] for e in res.epochs]
    assert "gw-a" in plans[0]                     # starts at home
    assert "gw-a" not in plans[1]                 # evacuated during outage
    assert "gw-a" in plans[2]                     # returns after recovery
    assert res.ledger.conserved()
    # determinism of the full controller loop
    res2 = _cosim(outages=outages).run(_online_ctrl())
    assert res2.vos == res.vos
    assert res2.ledger.totals() == res.ledger.totals()


def test_controller_regret_telemetry():
    """Every OnlineController epoch records forecast-ranked VoS (best
    plan vs the plan actually played) and the engine merges the realized
    co-sim VoS + calibration gap into the same record — the forecast-
    calibration measurement the ROADMAP item needs."""
    cs = _cosim()
    ctrl = _online_ctrl()
    res = cs.run(ctrl)
    assert len(ctrl.telemetry) == len(cs.epochs)
    epochs = res.summary()["epochs"]
    for e in epochs:
        fc = e["forecast"]
        assert fc["epoch"] == e["epoch"]
        assert fc["best_vos"] is not None
        assert fc["chosen_vos"] is not None
        # search_regret is *signed*: exactly best - chosen (negative
        # regret — a kept incumbent outscoring the searched best — is
        # recorded, not clamped; see test_feedback for both signs)
        assert fc["search_regret"] == pytest.approx(
            fc["best_vos"] - fc["chosen_vos"], abs=2e-4)
        # realized per-epoch VoS merged back by the engine
        assert fc["cosim_vos"] == e["vos"]
        assert fc["calibration_gap"] == pytest.approx(
            fc["chosen_vos"] - e["vos"], abs=1e-3)
    assert epochs[0]["forecast"]["switched"]      # first epoch adopts
    # static controllers have no telemetry, and their epochs say so
    r_static = cs.run(StaticController(
        PlacementPlan.all_edge(NAMES, site="gw-a")))
    assert all("forecast" not in e for e in r_static.summary()["epochs"])


def test_oracle_is_free_to_switch():
    """The oracle pays no migration stalls and sees true next-epoch
    rates; with identical decisions it can only do at least as well."""
    outages = {"gw-a": [(300.0, 600.0)]}
    r_onl = _cosim(outages=outages).run(_online_ctrl())
    r_orc = _cosim(outages=outages).run(OracleController(chips_options=(4,)))
    assert r_orc.vos >= r_onl.vos - 1e-9


def test_infeasible_plan_is_rejected():
    """A plan whose buffer budgets exceed a site's RAM raises up front."""
    profiles = {"agg": ServiceProfile(_SLO, flops_per_record=2e3),
                "smooth": ServiceProfile(_SLO, flops_per_record=2e3)}
    fleet = FleetSpec(sites=(
        SiteSpec("tiny", EdgeSpec(name="tiny", ram_bytes=1024.0),
                 farm_queues=("neubotspeed",)),))
    cfg = OnlineConfig(fleet=fleet, horizon_s=300.0, epoch_s=300.0)
    cs = FleetCoSimulator(_build(), profiles, cfg)
    with pytest.raises(ValueError, match="infeasible"):
        cs.run(StaticController(PlacementPlan.all_edge(NAMES, site="tiny")))
