"""Hierarchical regional fleets (repro.region): flat-vs-degenerate
bit-identity, LinkQueue FIFO regression, HierFleetSpec validation, JSON
round-trips (incl. the infinite transparent RAP), the decomposed
region search vs flat anchors with per-region screening budgets, the
search_placement front-door routing, and the BENCH_fleet.json schema
golden."""
import json
import math
import os

import pytest

from repro.online.controller import ForecastModel
from repro.online.fleet import (ContendedUplink, FleetSpec, LinkQueue,
                                SiteSpec, transparent_link)
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.placement.plan import SITE_DC, PlacementPlan, ServicePlacement
from repro.placement.search import search_placement
from repro.region import (DEFAULT_RAP, TRANSPARENT_RAP, FleetGenSpec,
                          HierFleetSpec, RegionSpec, generate_fleet,
                          hier_fleet_spec, partition_services,
                          region_search, region_search_exact, regions_view)
from repro.scenario import RateSpec, ScenarioSpec, scenario

_SLO_KW = dict(soft_latency_s=2.0, hard_latency_s=10.0,
               soft_energy_j=2.0, hard_energy_j=100.0)


def _two_site_spec(regions: bool, rap=None) -> ScenarioSpec:
    b = (scenario("hier-degenerate")
         .horizon(600.0)
         .site("gw-a", edge=EdgeSpec(name="gw-a", active_power_w=2.0),
               link=LinkSpec(uplink_bps=40e3, downlink_bps=2e6,
                             rtt_s=0.04), user=True)
         .site("gw-b", edge=EdgeSpec(name="gw-b", active_power_w=2.0),
               link=LinkSpec(uplink_bps=30e3, downlink_bps=2e6,
                             rtt_s=0.05))
         .farm(queue="neubotspeed", n_things=4, seed=3, site="gw-a",
               rate=RateSpec.constant(3.0))
         .service("agg", queue="neubotspeed", column="download_speed",
                  agg="max", width_s=120, slide_s=30)
         .slo(**_SLO_KW).profile(flops_per_record=2e3)
         .service("smooth", queue="agg_out", column="value", agg="mean",
                  width_s=120, slide_s=60)
         .fed_by("agg")
         .slo(**_SLO_KW).profile(flops_per_record=2e3))
    if regions:
        b.region("all", "gw-a", "gw-b", rap=rap or TRANSPARENT_RAP)
    return b.build()


_PLANS = (
    PlacementPlan({"agg": ServicePlacement("gw-a"),
                   "smooth": ServicePlacement("gw-a")}),
    PlacementPlan.all_dc(["agg", "smooth"], chips=4, dvfs_f=1.0),
    PlacementPlan({"agg": ServicePlacement("gw-b"),
                   "smooth": ServicePlacement(SITE_DC, 4, 1.0)}),
)


# -------------------------------------------- flat == degenerate hier
def test_flat_equals_transparent_one_region_bit_identical():
    """A flat fleet IS the degenerate one-region hierarchy behind a
    transparent RAP: every plan must score bit-identically (same VoS
    float, same ledger totals) through the unified engine."""
    flat = _two_site_spec(regions=False).compile()
    hier = _two_site_spec(regions=True).compile()
    for plan in _PLANS:
        rf, rh = flat.run_plan(plan), hier.run_plan(plan)
        assert rf.vos == rh.vos, plan.label          # exact, not approx
        assert rf.ledger.totals() == rh.ledger.totals(), plan.label


def test_opaque_rap_changes_cross_core_haul_only():
    """A real (finite) RAP taxes DC offload but must leave a purely
    local all-edge plan untouched."""
    flat = _two_site_spec(regions=False).compile()
    hier = _two_site_spec(regions=True, rap=DEFAULT_RAP).compile()
    local = _PLANS[0]                        # everything on gw-a
    assert flat.run_plan(local).vos == hier.run_plan(local).vos
    offload = _PLANS[1]                      # everything in the DC
    rf, rh = flat.run_plan(offload), hier.run_plan(offload)
    assert rh.vos <= rf.vos                  # trunk is never free


# ---------------------------------------------------- LinkQueue FIFO
def test_link_queue_fifo_admission():
    q = LinkQueue()
    assert q.admit(0.0, 2.0) == 0.0          # idle pipe: starts at once
    assert q.busy_until == 2.0
    assert q.admit(1.0, 1.0) == 2.0          # queues behind the first
    assert q.queue_wait_s == pytest.approx(1.0)
    assert q.admit(5.0, 1.0) == 5.0          # pipe drained: no wait
    assert q.transfers == 3
    assert q.queue_wait_s == pytest.approx(1.0)


def test_contended_uplink_is_link_queue():
    """The historical flat-fleet uplink is the same FIFO primitive now
    shared by every tier."""
    assert issubclass(ContendedUplink, LinkQueue)
    u = ContendedUplink()
    assert u.admit(0.0, 1.0) == 0.0 and u.admit(0.0, 1.0) == 1.0


def test_transparent_link_predicate():
    assert transparent_link(TRANSPARENT_RAP)
    assert not transparent_link(DEFAULT_RAP)


# ------------------------------------------------ HierFleetSpec rules
def _sites(*names):
    return tuple(SiteSpec(name=n, edge=EdgeSpec(name=n),
                          link=LinkSpec()) for n in names)


def test_hier_fleet_spec_requires_exact_partition():
    sites = _sites("a", "b", "c")
    ok = HierFleetSpec(sites=sites, regions=(
        RegionSpec("r0", ("a", "b"), DEFAULT_RAP),
        RegionSpec("r1", ("c",), DEFAULT_RAP)))
    assert ok.region_of("c") == "r1"
    with pytest.raises(ValueError):          # "c" uncovered
        HierFleetSpec(sites=sites, regions=(
            RegionSpec("r0", ("a", "b"), DEFAULT_RAP),))
    with pytest.raises(ValueError):          # "b" in two regions
        HierFleetSpec(sites=sites, regions=(
            RegionSpec("r0", ("a", "b"), DEFAULT_RAP),
            RegionSpec("r1", ("b", "c"), DEFAULT_RAP)))
    with pytest.raises(ValueError):          # unknown site
        HierFleetSpec(sites=sites, regions=(
            RegionSpec("r0", ("a", "b", "c", "ghost"), DEFAULT_RAP),))


def test_regions_view_flat_and_hier():
    flat = FleetSpec(sites=_sites("a", "b"))
    (r,) = regions_view(flat)
    assert r.transparent and set(r.sites) == {"a", "b"}
    hier = HierFleetSpec(sites=_sites("a", "b"), regions=(
        RegionSpec("r0", ("a",), DEFAULT_RAP),
        RegionSpec("r1", ("b",), TRANSPARENT_RAP)))
    view = regions_view(hier)
    assert [r.name for r in view] == ["r0", "r1"]
    assert not view[0].transparent and view[1].transparent


# ------------------------------------------------------- JSON round-trip
def test_hier_spec_json_roundtrip_including_infinite_rap():
    spec = _two_site_spec(regions=True)      # transparent: inf bps trunk
    blob = json.dumps(spec.to_dict())        # must survive JSON (inf!)
    back = ScenarioSpec.from_dict(json.loads(blob))
    assert back == spec
    assert math.isinf(back.regions[0].rap.uplink_bps)


def test_generated_spec_roundtrip_and_determinism():
    gen = FleetGenSpec(n_sites=12, n_regions=3, seed=5, horizon_s=600.0)
    spec = generate_fleet(gen)
    assert generate_fleet(gen) == spec       # pure function of the spec
    back = ScenarioSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert back == spec
    fleet = hier_fleet_spec(spec)
    assert len(fleet.regions) == 3
    assert sorted(s for r in fleet.regions for s in r.sites) \
        == sorted(fleet.site_names)


# -------------------------------------------------- decomposed search
@pytest.fixture(scope="module")
def small_hier():
    spec = generate_fleet(FleetGenSpec(
        n_sites=24, n_regions=3, seed=5, horizon_s=600.0,
        drift="constant", base_rate_hz=4.0))
    return spec, spec.compile()


def test_region_search_beats_flat_anchors(small_hier):
    spec, eng = small_hier
    sr = region_search(eng, chips_options=(4,), seed=0, sweeps=1)
    names = [s.name for s in spec.services]
    r_dc = eng.run_plan(PlacementPlan.all_dc(names, chips=4, dvfs_f=1.0))
    edge_of = {q: st.name for st in spec.sites for q in st.farm_queues}
    r_home = eng.run_plan(PlacementPlan(
        {s.name: ServicePlacement(edge_of[s.name[:3] + "-q"])
         for s in spec.services}))
    assert sr.result.feasible
    assert sr.result.vos >= r_dc.vos - 1e-9
    assert sr.result.vos >= r_home.vos - 1e-9
    assert sr.method == "region-screened"


def test_region_search_reports_per_region_budgets(small_hier):
    _, eng = small_hier
    sr = region_search(eng, chips_options=(4,), seed=0, sweeps=1)
    regions = sr.screen["regions"]
    assert len(regions) == 3
    for name, st in regions.items():
        assert {"services", "candidate_sites", "space", "top_k",
                "screened", "best_screen_vos"} <= set(st), name
        # the budget is the region's own: derived from ITS block space
        from repro.placement.search import _default_top_k
        assert st["top_k"] == _default_top_k(st["space"], 65536), name
    assert sr.screen["warm_started"] is False
    assert sr.screen["sweeps"] == 1


def test_partition_services_exact_cover(small_hier):
    spec, eng = small_hier
    fleet = hier_fleet_spec(spec)
    farm_site_of = {s.name: fleet.farm_site(s.queue)
                    for s in spec.services}
    parts = partition_services(fleet, spec.topology(), farm_site_of,
                               max_sites_per_region=4)
    covered = [s for p in parts for s in p.services]
    assert sorted(covered) == sorted(s.name for s in spec.services)
    region_sites = {r.name: set(r.sites) for r in fleet.regions}
    for p in parts:
        assert set(p.sites) <= region_sites[p.region]
        assert len(p.sites) <= 4
        # the farm sites the partition's chains are rooted at survive
        # the cap
        for svc in p.services:
            root_site = farm_site_of[svc.replace("svc1", "svc0")
                                     .replace("svc2", "svc0")]
            assert root_site in p.sites


def test_search_placement_front_door_routes_hier(small_hier):
    spec, eng = small_hier
    sr = search_placement(eng, chips_options=(4,), seed=0)
    assert sr.method == "region-screened"
    rates = {s.name: 4.0 for s in spec.services}
    model = ForecastModel(eng.info(), rates)
    sre = search_placement(model, chips_options=(4,), seed=0)
    assert sre.method == "region-exact"
    # warm start is honoured and can only help
    sre2 = search_placement(model, chips_options=(4,), seed=0,
                            warm_start=sre.plan)
    assert sre2.screen["warm_started"] is True
    assert sre2.result.vos >= sre.result.vos - 1e-9
    # forcing the flat path still works on a hierarchical fleet
    srf = search_placement(eng, chips_options=(4,), seed=0,
                           partition=False,
                           edge_sites=tuple(eng.cfg.fleet.site_names[:4]))
    assert srf.method not in ("region-screened", "region-exact")


def test_region_search_exact_beats_anchors(small_hier):
    spec, eng = small_hier
    rates = {s.name: 4.0 for s in spec.services}
    model = ForecastModel(eng.info(), rates)
    sr = region_search_exact(model, chips_options=(4,), seed=0)
    names = [s.name for s in spec.services]
    r_dc = model.run(PlacementPlan.all_dc(names, chips=4, dvfs_f=1.0))
    assert sr.result.vos >= r_dc.vos - 1e-9
    assert set(sr.screen["regions"]) \
        == {r.name for r in hier_fleet_spec(spec).regions}


# ------------------------------------------------- BENCH_fleet golden
_BENCH = os.path.join(os.path.dirname(__file__), "..", "BENCH_fleet.json")


@pytest.mark.skipif(not os.path.exists(_BENCH),
                    reason="no recorded BENCH_fleet.json")
def test_bench_fleet_report_schema_golden():
    """Schema golden for BENCH_fleet.json: the recorded planet-scale run
    must stay at >= 500 sites / >= 3 regions, keep its per-region
    screening budgets, and have passed every acceptance gate."""
    with open(_BENCH) as f:
        rep = json.load(f)
    assert {"smoke", "generated", "search", "online", "determinism",
            "acceptance", "wall_s", "wall_gate_s"} <= set(rep)
    assert rep["smoke"] is False
    g = rep["generated"]
    assert g["sites"] >= 500 and g["regions"] >= 3
    assert {"n_sites", "n_regions", "seed", "drift",
            "spec_sha256"} <= set(g)
    s = rep["search"]
    assert {"vos", "all_dc_vos", "home_edge_vos", "stats",
            "wall_s"} <= set(s)
    assert s["vos"] >= s["all_dc_vos"] and s["vos"] >= s["home_edge_vos"]
    budgets = s["stats"]["screen"]["regions"]
    assert len(budgets) >= 3
    for name, st in budgets.items():
        assert {"services", "candidate_sites", "space", "top_k",
                "screened"} <= set(st), name
    o = rep["online"]
    assert {"vos", "statics", "best_static", "search_methods",
            "epochs"} <= set(o)
    assert o["vos"] > o["best_static"]["vos"]
    assert o["search_methods"] == ["region-exact"]
    acc = rep["acceptance"]
    assert {"search_beats_flat_baselines", "online_beats_best_static",
            "warm_started_region_search", "ledger_conserved",
            "generator_deterministic", "wall_within_gate",
            "pass"} <= set(acc)
    assert acc["pass"] is True
    assert rep["wall_s"] <= rep["wall_gate_s"]
