"""Parallel + incremental planning hot path: ParallelEvaluator
bit-equality with the serial evaluator (any worker count, repeated
runs, broken-pool and in-process fallbacks), delta-aware block
screening vs the dense screen, cross-epoch evaluator-cache reuse (the
online controller's telemetry counters), and the sealed-plan
regression (mutating a plan after ``key()`` must raise)."""
import numpy as np
import pytest

from repro.online.controller import ForecastModel, OnlineController
from repro.placement.parallel import ParallelEvaluator, default_workers
from repro.placement.plan import (PlacementPlan, ServicePlacement,
                                  service_options)
from repro.placement.search import Evaluator, search_placement
from repro.region import FleetGenSpec, generate_fleet, region_search
from repro.region.search import _partition_from_screener


@pytest.fixture(scope="module")
def small_hier():
    spec = generate_fleet(FleetGenSpec(
        n_sites=24, n_regions=3, seed=5, horizon_s=600.0,
        drift="constant", base_rate_hz=4.0))
    return spec, spec.compile()


def _result_fields(r):
    return (r.vos, r.feasible, r.plan_label)


# ---------------------------------------------------- parallel == serial
def test_parallel_search_matches_serial_bit_identical(small_hier):
    """The whole decomposed search through a 2-worker pool must
    reproduce the serial evaluator exactly: winning plan, exact-DES
    VoS float, and the evaluator bookkeeping (hit/miss counters,
    history order)."""
    spec, eng = small_hier
    ser = Evaluator(eng)
    sr = region_search(eng, chips_options=(4,), seed=0, sweeps=1,
                       evaluator=ser)
    with ParallelEvaluator(eng, workers=2, spec=spec) as pev:
        sr2 = region_search(eng, chips_options=(4,), seed=0, sweeps=1,
                            evaluator=pev)
    assert sr2.plan.key() == sr.plan.key()
    assert sr2.result.vos == sr.result.vos           # exact, not approx
    assert _result_fields(sr2.result) == _result_fields(sr.result)
    assert (pev.hits, pev.misses) == (ser.hits, ser.misses)
    assert pev.history == ser.history                # same order, same vos


@pytest.mark.parametrize("workers", [1, 2, 4])
def test_parallel_determinism_across_worker_counts(small_hier, workers):
    """Worker count is a throughput knob, never a result knob."""
    spec, eng = small_hier
    ref = region_search(eng, chips_options=(4,), seed=0, sweeps=1)
    with ParallelEvaluator(eng, workers=workers, spec=spec) as pev:
        sr = region_search(eng, chips_options=(4,), seed=0, sweeps=1,
                           evaluator=pev)
    assert sr.plan.key() == ref.plan.key()
    assert sr.result.vos == ref.result.vos


def test_parallel_repeated_runs_identical(small_hier):
    spec, eng = small_hier

    def once():
        with ParallelEvaluator(eng, workers=2, spec=spec) as pev:
            sr = region_search(eng, chips_options=(4,), seed=0, sweeps=1,
                               evaluator=pev)
        return sr.plan.key(), sr.result.vos

    assert once() == once()


def test_parallel_in_process_fallback(small_hier):
    """workers<=1 never builds a pool: the batch runs the serial loop
    in the caller's process and the counters say so."""
    _, eng = small_hier
    names = list(eng.topology)
    plans = [PlacementPlan.all_dc(names, chips=c, dvfs_f=1.0)
             for c in (4, 8, 16)]
    pev = ParallelEvaluator(eng, workers=1)
    got = pev.evaluate_batch(plans)
    assert pev._pool is None
    assert pev.parallel_jobs == 0 and pev.serial_jobs == len(plans)
    ser = Evaluator(eng)
    assert [r.vos for r in got] == [ser(p).vos for p in plans]


def test_parallel_broken_pool_falls_back_serial(small_hier):
    """A pool that cannot start (or died) degrades to in-process
    evaluation with identical results."""
    _, eng = small_hier
    names = list(eng.topology)
    plans = [PlacementPlan.all_dc(names, chips=c, dvfs_f=1.0)
             for c in (4, 8)]
    pev = ParallelEvaluator(eng, workers=2)
    pev._pool_broken = True
    got = pev.evaluate_batch(plans)
    assert pev.serial_jobs == len(plans) and pev.parallel_jobs == 0
    ser = Evaluator(eng)
    assert [r.vos for r in got] == [ser(p).vos for p in plans]


def test_parallel_batch_cache_bookkeeping(small_hier):
    """Duplicate submissions and re-batched plans hit the memo exactly
    as the serial evaluator would."""
    _, eng = small_hier
    names = list(eng.topology)
    a = PlacementPlan.all_dc(names, chips=4, dvfs_f=1.0)
    b = PlacementPlan.all_dc(names, chips=8, dvfs_f=1.0)
    pev = ParallelEvaluator(eng, workers=1)
    pev.evaluate_batch([a, b, a])
    assert (pev.hits, pev.misses) == (1, 2)
    pev.evaluate_batch([b, a])
    assert (pev.hits, pev.misses) == (3, 2)
    assert default_workers() >= 1


# ------------------------------------------------- delta-aware screening
def test_region_search_delta_vs_dense_bit_identical(small_hier):
    """Force the dense per-block screen and re-run: the delta-aware
    path must have produced the same winner from the same screen
    scores (the delta stats prove it actually ran)."""
    _, eng = small_hier
    screener = eng.screening_model()
    before = screener.delta_stats()
    sr_delta = region_search(eng, chips_options=(4,), seed=0, sweeps=1)
    after = screener.delta_stats()
    assert after["delta_calls"] > before["delta_calls"]
    assert after["cells_saved"] > before["cells_saved"]
    screener.score_block = None           # Evaluator falls back to dense
    try:
        sr_dense = region_search(eng, chips_options=(4,), seed=0, sweeps=1)
    finally:
        del screener.score_block
    assert sr_delta.plan.key() == sr_dense.plan.key()
    assert sr_delta.result.vos == sr_dense.result.vos


def test_score_block_matches_dense_direct(small_hier):
    """score_block on a single region's columns == the dense
    score_matrix on the same full-width rows, bit for bit."""
    _, eng = small_hier
    m = eng.screening_model()
    order = list(m.order)
    rank = {s: i for i, s in enumerate(order)}
    fleet = eng.cfg.fleet
    parts = _partition_from_screener(m, fleet, 12)
    all_sites = [s for part in parts for s in part.sites]
    options = service_options((4,), (1.0,), all_sites)
    dc_opts = [i for i, o in enumerate(options) if not o.is_edge]
    site_opt = {o.site: i for i, o in enumerate(options) if o.is_edge}
    base = np.full(len(order), dc_opts[0], dtype=int)
    rng = np.random.default_rng(7)
    ran_delta = False
    for part in parts:
        cols = [rank[s] for s in part.services]
        sub = np.asarray([site_opt[s] for s in part.sites] + dc_opts)
        P = np.tile(base, (32, 1))
        P[:, cols] = sub[rng.integers(0, len(sub), (32, len(cols)))]
        before = m.delta_stats()
        got = m.score_block(P, cols, options)
        if m.delta_stats()["delta_calls"] > before["delta_calls"]:
            ran_delta = True
        want = m.score_matrix(P, options)
        assert np.array_equal(got, want), part.region
    assert ran_delta       # at least one block took the incremental path


def test_score_block_guard_falls_back_dense(small_hier):
    """Pinned occupancy inside the block's own region breaks the
    disjointness guard: score_block must take the dense fallback (and
    count it), still bit-identical."""
    _, eng = small_hier
    m = eng.screening_model()
    order = list(m.order)
    rank = {s: i for i, s in enumerate(order)}
    parts = _partition_from_screener(m, eng.cfg.fleet, 12)
    part = parts[0]
    all_sites = [s for p in parts for s in p.sites]
    options = service_options((4,), (1.0,), all_sites)
    dc_opts = [i for i, o in enumerate(options) if not o.is_edge]
    site_opt = {o.site: i for i, o in enumerate(options) if o.is_edge}
    cols = [rank[s] for s in part.services[:-1]]
    if not cols:
        pytest.skip("single-service partition")
    base = np.full(len(order), dc_opts[0], dtype=int)
    # pin the held-out service onto one of the block's own edge sites
    base[rank[part.services[-1]]] = site_opt[part.sites[0]]
    sub = np.asarray([site_opt[s] for s in part.sites] + dc_opts)
    P = np.tile(base, (8, 1))
    P[:, cols] = sub[np.random.default_rng(3).integers(
        0, len(sub), (8, len(cols)))]
    before = m.delta_stats()["dense_fallbacks"]
    got = m.score_block(P, cols, options)
    assert m.delta_stats()["dense_fallbacks"] == before + 1
    assert np.array_equal(got, m.score_matrix(P, options))


# ------------------------------------------------- cross-epoch cache reuse
def test_evaluator_shared_cache_namespaced_by_prefix(small_hier):
    """One memo dict shared across evaluators: the same model
    fingerprint reuses scores wholesale, a different fingerprint must
    not (stale scores from an old forecast would rank wrongly)."""
    spec, eng = small_hier
    info = eng.info()
    rates = {s: 4.0 for s in eng.order}
    model = ForecastModel(info, rates)
    shared: dict = {}
    ev1 = Evaluator(model, cache=shared, key_prefix=("fp-a",))
    sr1 = search_placement(model, chips_options=(4,), seed=0,
                           edge_sites=info.fleet.site_names, evaluator=ev1)
    assert sr1.cache_misses > 0
    ev2 = Evaluator(model, cache=shared, key_prefix=("fp-a",))
    sr2 = search_placement(model, chips_options=(4,), seed=0,
                           edge_sites=info.fleet.site_names, evaluator=ev2)
    assert sr2.plan.key() == sr1.plan.key()
    assert sr2.cache_misses == 0 and sr2.cache_hits > 0
    ev3 = Evaluator(model, cache=shared, key_prefix=("fp-b",))
    sr3 = search_placement(model, chips_options=(4,), seed=0,
                           edge_sites=info.fleet.site_names, evaluator=ev3)
    assert sr3.cache_misses == sr1.cache_misses    # namespace isolated


def test_controller_telemetry_cross_epoch_counters():
    """Every online epoch reports the run-cumulative shared-cache
    counters; they reconcile with the per-epoch ones and the cache
    actually persists across epochs."""
    spec = generate_fleet(FleetGenSpec(
        n_sites=8, n_regions=2, seed=42, drift="constant",
        horizon_s=600.0, epoch_s=150.0))
    eng = spec.compile()
    ctrl = OnlineController(chips_options=(4,), window=1,
                            switch_margin=0.02, seed=0)
    eng.run(ctrl)
    assert len(ctrl.telemetry) >= 2
    cum_h = cum_m = 0
    for e in ctrl.telemetry:
        s = e["search"]
        assert {"cum_cache_hits", "cum_cache_misses", "cache_plans",
                "model_reused"} <= set(s)
        cum_h += s["cache_hits"]
        cum_m += s["cache_misses"]
        assert s["cum_cache_hits"] == cum_h
        assert s["cum_cache_misses"] == cum_m
        assert s["cache_plans"] > 0           # memo persists across epochs
    assert len(ctrl._xcache) == ctrl.telemetry[-1]["search"]["cache_plans"]


def test_controller_cache_reuse_is_bit_identical():
    """The shared cache is an optimization, not a behavior change: the
    same run with the memo forcibly disabled (cleared each epoch via a
    fresh bind-equivalent) plays the identical plan sequence."""
    spec = generate_fleet(FleetGenSpec(
        n_sites=8, n_regions=2, seed=42, drift="constant",
        horizon_s=600.0, epoch_s=150.0))

    def run(ctrl):
        r = spec.compile().run(ctrl)
        return r.vos, [e["chosen_vos"] for e in ctrl.telemetry]

    a = run(OnlineController(chips_options=(4,), window=1,
                             switch_margin=0.02, seed=0))
    ctrl_nc = OnlineController(chips_options=(4,), window=1,
                               switch_margin=0.02, seed=0)
    orig = ctrl_nc._model_fingerprint
    calls = iter(range(10 ** 6))
    # unique fingerprint per epoch -> every lookup misses -> no reuse
    ctrl_nc._model_fingerprint = (
        lambda *a_, **k: orig(*a_, **k) + (next(calls),))
    b = run(ctrl_nc)
    assert a == b


# ------------------------------------------------------ sealed-plan memo
def test_plan_mutation_after_key_rejected():
    """Regression: key() seals the plan — a mutation afterwards would
    silently alias a stale memo entry onto the wrong plan."""
    plan = PlacementPlan({"agg": ServicePlacement("gw-a"),
                          "smooth": ServicePlacement("gw-a")})
    plan.assignments["smooth"] = ServicePlacement("gw-b")   # still open
    k = plan.key()
    assert plan.key() is k                   # memoized, not recomputed
    with pytest.raises(TypeError):
        plan.assignments["agg"] = ServicePlacement("gw-b")
    with pytest.raises(TypeError):
        del plan.assignments["agg"]
    with pytest.raises(TypeError):
        plan.assignments.update({"agg": ServicePlacement("gw-b")})
    with pytest.raises(TypeError):
        plan.assignments.clear()
    # the sealed plan still reads fine and its key is stable
    assert plan.site("smooth") == "gw-b"
    assert plan.key() == k
