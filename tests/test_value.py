"""Hypothesis property tests on the VoS value system (Fig. 3 / Eq. 1-2)."""
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't crash collection
from hypothesis import given, settings, strategies as st

from repro.core.value import TaskValueSpec, ValueCurve, task_value, vos_total

pos = st.floats(0.01, 1e6, allow_nan=False, allow_infinity=False)


@st.composite
def curves(draw):
    v_min = draw(st.floats(0.0, 1.0))
    v_max = draw(st.floats(v_min, v_min + 10.0))
    soft = draw(pos)
    hard = soft * draw(st.floats(1.0, 10.0))
    shape = draw(st.sampled_from(["linear", "exponential"]))
    return ValueCurve(v_max, v_min, soft, hard, shape)


@settings(max_examples=200, deadline=None)
@given(curves(), pos, pos)
def test_curve_monotone_nonincreasing(c, x1, x2):
    lo, hi = sorted((x1, x2))
    assert c.value(lo) >= c.value(hi) - 1e-12


@settings(max_examples=200, deadline=None)
@given(curves(), pos)
def test_curve_bounds_and_thresholds(c, x):
    v = c.value(x)
    assert 0.0 <= v <= c.v_max
    if x <= c.th_soft:
        assert v == c.v_max
    if x > c.th_hard:
        assert v == 0.0


@settings(max_examples=100, deadline=None)
@given(curves(), curves(), st.floats(0.1, 8), st.floats(0, 1), pos, pos)
def test_task_value_zero_rule_and_bounds(pc, ec, gamma, w_p, lat, en):
    spec = TaskValueSpec(gamma=gamma, w_p=w_p, w_e=1 - w_p,
                         perf_curve=pc, energy_curve=ec)
    v = task_value(spec, lat, en)
    assert 0.0 <= v <= gamma * (w_p * pc.v_max + (1 - w_p) * ec.v_max) + 1e-9
    # Eq. 1 zero rule: either component at zero kills the whole value
    if pc.value(lat) == 0.0 or ec.value(en) == 0.0:
        assert v == 0.0


def test_vos_is_sum():
    assert vos_total([1.0, 2.5, 0.0]) == 3.5


def test_invalid_curve_rejected():
    with pytest.raises(ValueError):
        ValueCurve(1.0, 0.0, 10.0, 5.0)
    with pytest.raises(ValueError):
        ValueCurve(1.0, 2.0, 1.0, 5.0)
