"""Simulator + heuristics: the paper's quantitative claims (Fig. 4) and
structural invariants."""
import statistics as stats

import pytest

from repro import hardware as hw
from repro.core.costmodel import CostModel
from repro.core.heuristics import HEURISTICS
from repro.core.simulator import Simulator, compare_heuristics
from repro.core.tasks import PAPER_REGIME, TaskType, WorkloadGenerator

ARCHS = ["smollm-135m", "qwen3-1.7b", "yi-6b", "olmoe-1b-7b", "mamba2-1.3b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


@pytest.fixture(scope="module")
def cost():
    return CostModel.analytic()


def _trace_fn(cost):
    types = [TaskType(a, s) for a in ARCHS for s in SHAPES]

    def fn(i):
        return WorkloadGenerator(types, cost, seed=100 + i,
                                 **PAPER_REGIME).trace(150)
    return fn


def test_conservation_and_determinism(cost):
    trace_fn = _trace_fn(cost)
    r1 = Simulator(HEURISTICS["VPTR"], cost).run(trace_fn(0))
    r2 = Simulator(HEURISTICS["VPTR"], cost).run(trace_fn(0))
    assert r1.vos == r2.vos and r1.completed == r2.completed
    assert r1.completed + r1.dropped == 150
    assert 0.0 <= r1.vos_normalized <= 1.0
    assert r1.total_energy_j > 0


def test_fig4_vptr_beats_simple_in_paper_band(cost):
    """Fig. 4: VPTR over Simple — ≈+50% energy value, ≈+40% perf value,
    up to +71% normalized VoS. Calibrated regime must land every gain
    positive and in a sane band around the paper's numbers."""
    res = compare_heuristics([HEURISTICS["Simple"], HEURISTICS["VPTR"]],
                             cost, _trace_fn(cost), n_traces=4)
    mean = lambda k, n: stats.mean(getattr(r, k) for r in res[n])
    vos_gain = mean("vos_normalized", "VPTR") / mean("vos_normalized",
                                                     "Simple") - 1
    perf_gain = mean("perf_value", "VPTR") / mean("perf_value", "Simple") - 1
    energy_gain = mean("energy_value", "VPTR") / mean("energy_value",
                                                      "Simple") - 1
    assert 0.20 < vos_gain < 1.30, vos_gain
    assert 0.20 < perf_gain < 1.30, perf_gain
    assert 0.20 < energy_gain < 1.30, energy_gain
    # "up to 71%": the best trace should reach at least the mean band
    best = max(v.vos_normalized / s.vos_normalized - 1
               for v, s in zip(res["VPTR"], res["Simple"]))
    assert best > 0.30


def test_fig5_power_cap_pattern(cost):
    """Fig. 5 pattern: every heuristic's earnings are non-decreasing as the
    cap relaxes 55→85%, and the power-aware family ends above plain VPT at
    the relaxed caps."""
    names = ["VPT", "VPT-CPC", "VPT-JSPC", "Hybrid"]
    hs = [HEURISTICS[n] for n in names]
    trace_fn = _trace_fn(cost)
    by_cap = {}
    for frac in (0.55, 0.70, 0.85):
        cap = hw.pod_power_cap_w(frac)
        res = compare_heuristics(hs, cost, trace_fn, n_traces=3,
                                 power_cap_w=cap)
        by_cap[frac] = {n: stats.mean(r.vos_normalized for r in res[n])
                        for n in names}
    for n in names:
        assert by_cap[0.55][n] <= by_cap[0.70][n] + 0.02
        assert by_cap[0.70][n] <= by_cap[0.85][n] + 0.02
    for frac in (0.70, 0.85):
        aware = max(by_cap[frac][n] for n in ("VPT-CPC", "VPT-JSPC",
                                              "Hybrid"))
        assert aware > by_cap[frac]["VPT"]


def test_power_cap_never_violated(cost):
    """Hard constraint: at assignment time projected power ≤ cap."""
    cap = hw.pod_power_cap_w(0.55)
    trace = _trace_fn(cost)(0)

    from repro.core.vdc import PodGrid
    grid = PodGrid()
    h = HEURISTICS["VPT-JSPC"]
    assigns = h.assign(trace[:30], grid, cost, now=1e4, power_cap_w=cap)
    total = grid.power_w(cost) + sum(
        cost.power_w(c, f) for _, c, f in assigns)
    # grid.power_w already counts idle static; new VDCs add their own draw
    assert total <= cap + grid.free_chips * hw.CHIP_STATIC_W


# ---------------------------------------------------------------------------
# Incremental event-feed API (begin / inject / run_until / finalize)
# ---------------------------------------------------------------------------
def test_incremental_feed_matches_one_shot(cost):
    """Feeding the trace in chunks through the live event heap must be
    event-for-event identical to the classic full-trace run()."""
    import copy
    trace = _trace_fn(cost)(3)[:60]
    ref = Simulator(HEURISTICS["VPTR"], cost).run(copy.deepcopy(trace))

    inc_trace = copy.deepcopy(trace)
    sim = Simulator(HEURISTICS["VPTR"], cost)
    sim.begin()
    mid = inc_trace[len(inc_trace) // 2].arrival
    for t in inc_trace:
        if t.arrival <= mid:
            sim.inject(t)
    sim.run_until(mid)                    # advance with half the future
    for t in inc_trace:
        if t.arrival > mid:               # injected mid-flight
            sim.inject(t)
    res = sim.finalize()

    assert res.vos == ref.vos
    assert res.completed == ref.completed
    assert res.dropped == ref.dropped
    assert res.total_energy_j == ref.total_energy_j


def test_inject_after_start_and_late_arrival(cost):
    """Tasks pushed after the clock has advanced are admitted at the
    current time but their value latency runs from the true arrival."""
    import copy
    trace = _trace_fn(cost)(4)[:10]
    sim = Simulator(HEURISTICS["VPTR"], cost)
    sim.begin()
    late = copy.deepcopy(trace[0])
    late.arrival = 0.0
    sim.run_until(5_000.0)
    assert sim.now == 5_000.0
    sim.inject(late)                      # nominal arrival is in the past
    res = sim.finalize()
    assert res.completed + res.dropped == 1
    if late.finish is not None:
        assert late.finish >= 5_000.0     # could not start before admission


def test_withdraw_counts_as_drop(cost):
    from repro.core.vdc import PodGrid
    trace = _trace_fn(cost)(5)[:3]
    # a 16-chip grid holds one job; later arrivals queue as pending
    sim = Simulator(HEURISTICS["VPTR"], cost, grid=PodGrid(4, 4))
    sim.begin()
    for t in trace:
        sim.inject(t)
    sim.run_until(max(t.arrival for t in trace) + 1e-6)
    target = next((t for t in sim.pending_tasks()), None)
    if target is not None:                # withdraw a genuinely queued task
        assert sim.withdraw(target)
        assert target.dropped
        assert target not in sim.pending_tasks()
    res = sim.finalize()
    assert res.completed + res.dropped == 3


def test_pending_order_and_drop_memo(cost):
    """The indexed pending queue must preserve arrival order (heuristics
    see the same queue the O(n)-list version exposed), and the memoized
    drop scan must agree with a fresh _best_possible computation for
    every task it keeps or drops."""
    from repro.core.simulator import _best_possible

    trace = _trace_fn(cost)(6)[:40]
    from repro.core.vdc import PodGrid
    sim = Simulator(HEURISTICS["VPTR"], cost, grid=PodGrid(4, 4))
    sim.begin()
    for t in trace:
        sim.inject(t)
    mid = trace[20].arrival
    sim.run_until(mid)
    pend = sim.pending_tasks()
    assert pend == sorted(pend, key=lambda t: t.arrival)
    now = sim.now
    for t in pend:      # survivors really are alive under the base rule
        v, _, _ = _best_possible(t, cost, now,
                                 max(t.ttype.allowable_chips))
        assert v > 0.0
    for t in trace:     # and every memo-dropped task is dead under it
        if t.dropped:
            v, _, _ = _best_possible(t, cost, now,
                                     max(t.ttype.allowable_chips))
            assert v <= 0.0
    res = sim.finalize()
    assert res.completed + res.dropped == len(trace)


def test_elastic_regrow_gains_value(cost):
    from repro.core.elastic import plan_regrow
    from repro.core.vdc import PodGrid
    trace = _trace_fn(cost)(1)
    task = trace[0]
    grid = PodGrid()
    vdc = grid.compose(16, 1.0, task.tid)
    t0 = task.arrival
    t_step = cost.time_per_step(task.ttype.arch, task.ttype.shape, 16, 1.0)
    task.start, task.finish = t0, t0 + t_step * task.steps
    task.chips = 16
    mig = plan_regrow([(task, vdc)], grid, cost, now=t0 + 1.0)
    if mig is not None:
        assert mig.new_chips > mig.old_chips
        assert mig.gain > 0
