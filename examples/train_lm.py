"""End-to-end training driver: train smollm-135m (the ~100M assigned arch)
for a few hundred steps with checkpointing and failure recovery.

On this CPU container the default uses the reduced config so a few hundred
steps finish in minutes; pass --full on real hardware for the exact
assigned 135M configuration (same code path).

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse

import numpy as np

from repro.launch.train import train_loop
from repro.train import TrainHParams

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--full", action="store_true",
                help="full 135M config (use on real hardware)")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
ap.add_argument("--p-fail", type=float, default=0.01,
                help="injected failure probability per step")
args = ap.parse_args()

hp = TrainHParams(peak_lr=3e-3, warmup_steps=20, total_steps=args.steps,
                  grad_accum=2, remat="full")
state, losses = train_loop(
    "smollm-135m", steps=args.steps, batch=8, seq=128, full=args.full,
    ckpt_dir=args.ckpt_dir, save_every=50, p_fail=args.p_fail, hp=hp,
    log_every=25)
print(f"\nloss: {np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f} "
      f"over {len(losses)} recorded steps (incl. replays after restarts)")
assert np.mean(losses[-10:]) < np.mean(losses[:10]), "did not learn!"
print("OK: model learned the synthetic Markov stream through failures.")
