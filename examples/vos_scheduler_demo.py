"""JITA-4DS in action: watch the VoS scheduler compose/release VDCs on the
pod grid under a power cap, comparing heuristics on one trace.

  PYTHONPATH=src python examples/vos_scheduler_demo.py
"""
from repro import hardware as hw
from repro.core.costmodel import CostModel
from repro.core.heuristics import HEURISTICS
from repro.core.simulator import Simulator
from repro.core.tasks import PAPER_REGIME, TaskType, WorkloadGenerator

cost = CostModel.analytic()
types = [TaskType(a, s)
         for a in ("smollm-135m", "qwen3-1.7b", "yi-6b", "olmoe-1b-7b",
                   "jamba-v0.1-52b", "mamba2-1.3b")
         for s in ("train_4k", "prefill_32k", "decode_32k")]
trace_gen = WorkloadGenerator(types, cost, seed=7, **PAPER_REGIME)

print(f"{'heuristic':10s} {'VoS':>8s} {'norm':>6s} {'done':>5s} "
      f"{'drop':>5s} {'util':>5s} {'energy MJ':>10s}")
cap = hw.pod_power_cap_w(0.70)
for name in ("Simple", "VPT", "VPTR", "VPT-CPC", "VPT-JSPC", "Hybrid"):
    import copy
    trace = copy.deepcopy(trace_gen.trace(120))
    r = Simulator(HEURISTICS[name], cost, power_cap_w=cap).run(trace)
    print(f"{name:10s} {r.vos:8.1f} {r.vos_normalized:6.3f} "
          f"{r.completed:5d} {r.dropped:5d} {r.avg_utilization:5.0%} "
          f"{r.total_energy_j/1e6:10.1f}")

print("\nVDC composition trace (VPTR, first 8 scheduled jobs):")
trace = trace_gen.trace(40)
r = Simulator(HEURISTICS["VPTR"], cost).run(trace)
for t in [t for t in r.tasks if t.start is not None][:8]:
    print(f"  t={t.start:8.0f}s  job{t.tid:3d} {t.ttype.name:30s} "
          f"VDC={t.chips:3d} chips f={t.dvfs_f:.1f} "
          f"-> V={t.earned:.2f}")
