"""Edge↔DC placement in action: declare the benchmark's heavy-analytics
Neubot scenario as a ScenarioSpec, compile it into the unified
DES-bridged engine, co-simulate every placement of interest and watch
the search pick the SLO-optimal split — the heavy CNN-scoring service
offloaded onto a JIT-composed VDC, the cheap aggregations left on the
gateway.

Reuses the exact spec from ``benchmarks/bench_placement.py`` so the
demo always illustrates the benchmarked behavior.

  PYTHONPATH=src python examples/edge_offload_demo.py [--smoke]
"""
import dataclasses
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))   # repro without PYTHONPATH
sys.path.insert(0, _ROOT)                        # benchmarks package

from benchmarks.bench_placement import scenario_heavy_analytics  # noqa: E402
from repro.placement import PlacementPlan, search_placement      # noqa: E402

SMOKE = "--smoke" in sys.argv

sc = scenario_heavy_analytics()
spec = sc.spec
if SMOKE:
    spec = dataclasses.replace(spec, horizon_s=240.0)
engine = spec.compile()
names = list(engine.topology)
print(f"scenario: {spec.name} (spec -> compile -> run)")
print(f"pipeline DAG: {engine.topology}\n")

print(f"{'plan':46s} {'VoS':>7s} {'norm':>6s} {'p95 lat':>8s} "
      f"{'edge J':>8s} {'net J':>7s} {'DC J':>8s}")
for plan in (PlacementPlan.all_edge(names),
             PlacementPlan.all_dc(names, chips=sc.chips_options[0])):
    r = engine.run_plan(plan)
    print(f"{plan.label:46s} {r.vos:7.2f} {r.vos_normalized:6.3f} "
          f"{r.latency_p95:8.3f} {r.edge_energy_j:8.2f} "
          f"{r.network_energy_j:7.3f} {r.dc_energy_j:8.2f}")

sr = search_placement(engine, chips_options=sc.chips_options,
                      dvfs_options=(1.0,) if SMOKE else (1.0, 0.7))
r = sr.result
print(f"{sr.plan.label:46s} {r.vos:7.2f} {r.vos_normalized:6.3f} "
      f"{r.latency_p95:8.3f} {r.edge_energy_j:8.2f} "
      f"{r.network_energy_j:7.3f} {r.dc_energy_j:8.2f}"
      f"   <- searched ({sr.method}, {sr.evaluations} evals)")

print("\nper-service co-sim of the searched plan:")
for name, s in r.per_service.items():
    print(f"  {name:10s} {s['site']:10s} fires={s['fires']:3d} "
          f"done={s['completed']:3d} drop={s['dropped']:3d} "
          f"VoS={s['vos']:7.2f} p95={s['latency_p95']:.3f}s")

print("\nrecord conservation (per ingest service):")
for name, sl in r.ledger.services.items():
    print(f"  {name:10s} produced={sl.produced:6d} edge={sl.processed_edge:6d} "
          f"dc={sl.processed_dc:6d} in-flight={sl.in_flight:5d} "
          f"dropped={sl.dropped:4d} conserved={sl.conserved()}")

if r.dc is not None:
    print(f"\nDC side: {r.dc.completed} VDC tasks completed, "
          f"{r.dc.dropped} dropped, utilization={r.dc.avg_utilization:.1%}, "
          f"heuristic={r.dc.heuristic}")

assert r.feasible and r.ledger.conserved(), "demo co-sim must conserve"
print("\nOK" if SMOKE else "")
