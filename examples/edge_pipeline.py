"""The paper's §3 use case end-to-end: an IoT farm of 'things' measuring
network quality, stream services answering the two Neubot queries, and the
just-in-time edge→VDC offload when a window outgrows the edge.

  PYTHONPATH=src python examples/edge_pipeline.py
"""
import time

import numpy as np

from repro.pipeline import (Broker, HybridExecutor, NeubotFarm, Pipeline,
                            TimeSeriesStore, neubot_query_1)
from repro.pipeline.operators import WindowSpec, kmeans
from repro.pipeline.service import ServiceConfig, StreamService

broker = Broker()
store = TimeSeriesStore("speedtests", chunk_seconds=3600,
                        edge_budget_chunks=6)
farm = NeubotFarm(broker, queue="neubotspeed", n_things=8, rate_hz=1.0)

# Q1: EVERY 60s MAX(download_speed) over the last 3 minutes
q1 = neubot_query_1(broker, store)
# a second mash-up: mean latency every 5 minutes (landmark window)
q3 = StreamService(ServiceConfig(
    name="latency_landmark", queue="neubotspeed", column="latency_ms",
    agg="mean", window=WindowSpec("landmark", 0.0, 300.0), store=store),
    broker)

pipe = Pipeline(broker).add_farm(farm).add_service(q1).add_service(q3)
pipe.connect(q1, "q1_results")  # q1's sink feeds a downstream queue

t0 = time.perf_counter()
out = pipe.advance_to(4 * 3600.0)  # four simulated hours
wall = time.perf_counter() - t0
print(f"4h of streams from 8 things in {wall:.1f}s wall")
print(f"Q1 fired {len(out['q1_max_speed'])}x; last 3 values "
      f"{[f'{r[1]:.1f}Mbps' for r in [(r['ts'], r['value']/1e6) for r in out['q1_max_speed'][-3:]]]}")
print(f"landmark latency: {out['latency_landmark'][-1]['value']:.1f} ms "
      f"over {out['latency_landmark'][-1]['n']} records")
print(f"store: {store.resident_chunks} edge-resident chunks, "
      f"{store.spill_events} spilled to VDC storage")

# Q2-scale: 120-day history doesn't fit the edge -> JIT offload to the VDC
hx = HybridExecutor(edge_budget=100_000)
history = np.abs(np.random.default_rng(0).standard_normal(
    10_368_000)).astype(np.float32) * 20e6  # 120d @ 1Hz
t0 = time.perf_counter()
mean = hx.run_window(history, "mean")
print(f"Q2 (120-day mean, {len(history):,} records): {mean/1e6:.2f} Mbps in "
      f"{time.perf_counter()-t0:.2f}s via "
      f"{'VDC offload' if hx.offloads else 'edge'} "
      f"(paper: 'order of seconds')")

# downstream analytics service: k-means on (download, latency) features
recs = list(broker.queue("neubotspeed").buf)[-2000:]
feats = np.array([[r.values["download_speed"] / 1e6,
                   r.values["latency_ms"]] for r in recs], np.float32)
centers, assign = kmeans(feats, k=3, iters=15)
print("k-means connectivity clusters (Mbps, ms):")
for c in np.asarray(centers):
    print(f"  ({c[0]:6.1f}, {c[1]:5.1f})")
