"""The paper's §3 use case end-to-end, declared through the Scenario
API: an IoT farm of 'things' measuring network quality, stream services
answering the Neubot queries (Q1 as a ~10-line declarative spec), and
the just-in-time edge→VDC offload when a window outgrows the edge.

  PYTHONPATH=src python examples/edge_pipeline.py [--smoke]
"""
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.pipeline import HybridExecutor  # noqa: E402
from repro.pipeline.operators import kmeans
from repro.scenario import RateSpec, scenario

SMOKE = "--smoke" in sys.argv
HOURS = 0.5 if SMOKE else 4.0

# Q1: EVERY 60s MAX(download_speed) over the last 3 minutes, plus a
# landmark mean-latency mash-up — one declarative spec, JSON round-trip
spec = (scenario("neubot-use-case")
        .horizon(HOURS * 3600.0)
        .farm(queue="neubotspeed", n_things=8, rate=RateSpec.constant(1.0))
        .service("q1_max_speed", queue="neubotspeed",
                 column="download_speed", agg="max",
                 width_s=180.0, slide_s=60.0)
        .with_store(chunk_seconds=3600.0, edge_budget_chunks=6)
        .service("latency_landmark", queue="neubotspeed",
                 column="latency_ms", agg="mean", window_kind="landmark",
                 width_s=0.0, slide_s=300.0)
        .with_store(chunk_seconds=3600.0, edge_budget_chunks=6)
        .build())
assert spec == type(spec).from_json(spec.to_json()), "spec must round-trip"

pipe = spec.build_pipeline()
t0 = time.perf_counter()
out = pipe.advance_to(spec.horizon_s)
wall = time.perf_counter() - t0
q1 = pipe.services[0].results
lmk = pipe.services[1].results
print(f"{HOURS:g}h of streams from 8 things in {wall:.1f}s wall "
      f"(spec: {len(spec.to_json())} JSON bytes)")
print(f"Q1 fired {len(q1)}x; last 3 values "
      f"{[f'{r[1]:.1f}Mbps' for r in [(r['ts'], r['value']/1e6) for r in q1[-3:]]]}")
print(f"landmark latency: {lmk[-1]['value']:.1f} ms "
      f"over {lmk[-1]['n']} records")
store = pipe.services[0].cfg.store
print(f"store: {store.resident_chunks} edge-resident chunks, "
      f"{store.spill_events} spilled to VDC storage")

# Q2-scale: a 120-day history doesn't fit the edge -> JIT offload to the
# VDC (scaled down in --smoke so CI stays fast)
hx = HybridExecutor(edge_budget=100_000)
n_hist = 1_000_000 if SMOKE else 10_368_000   # 120d @ 1Hz when full
history = np.abs(np.random.default_rng(0).standard_normal(
    n_hist)).astype(np.float32) * 20e6
t0 = time.perf_counter()
mean = hx.run_window(history, "mean")
print(f"Q2 ({n_hist:,}-record mean): {mean/1e6:.2f} Mbps in "
      f"{time.perf_counter()-t0:.2f}s via "
      f"{'VDC offload' if hx.offloads else 'edge'} "
      f"(paper: 'order of seconds')")

# downstream analytics service: k-means on (download, latency) features
recs = list(pipe.broker.queue("neubotspeed").buf)[-2000:]
feats = np.array([[r.values["download_speed"] / 1e6,
                   r.values["latency_ms"]] for r in recs], np.float32)
centers, assign = kmeans(feats, k=3, iters=15)
print("k-means connectivity clusters (Mbps, ms):")
for c in np.asarray(centers):
    print(f"  ({c[0]:6.1f}, {c[1]:5.1f})")

if SMOKE:
    assert len(q1) > 0 and lmk, "smoke: queries must fire"
    print("OK")
