"""Quickstart: build an assigned architecture, run a forward pass, and ask
the JITA-4DS scheduler to compose a VDC for it.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs
from repro.core.costmodel import CostModel
from repro.core.heuristics import HEURISTICS
from repro.core.simulator import Simulator
from repro.core.tasks import PAPER_REGIME, TaskType, WorkloadGenerator
from repro.data import make_batch
from repro.models import model as M

print("assigned architectures:", ", ".join(list_archs()))

# --- 1. a model (reduced config: CPU-sized, same code path as the full one)
cfg = get_arch("qwen3-1.7b").reduced()
params = M.init_params(cfg, jax.random.PRNGKey(0))
batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 64, 2, 0).items()}
logits, aux = jax.jit(lambda p, b: M.forward(cfg, p, b))(params, batch)
print(f"forward: logits {logits.shape}, aux loss {float(aux):.4f}")

# --- 2. the paper's scheduler composing VDCs for a small workload
cost = CostModel.analytic()
types = [TaskType(a, "train_4k") for a in ("smollm-135m", "yi-6b")]
trace = WorkloadGenerator(types, cost, seed=0, **PAPER_REGIME).trace(10)
result = Simulator(HEURISTICS["VPTR"], cost).run(trace)
print(f"VPTR plan: completed {result.completed}/10 jobs, "
      f"VoS={result.vos:.1f} (normalized {result.vos_normalized:.2f}), "
      f"utilization {result.avg_utilization:.0%}")
for t in result.tasks[:5]:
    state = "dropped" if t.dropped else (
        f"{t.chips} chips @f={t.dvfs_f:.1f} V={t.earned:.2f}")
    print(f"  job {t.tid} {t.ttype.name:28s} -> {state}")
