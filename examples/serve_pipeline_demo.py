"""Live serving in action: one declarative ScenarioSpec, two executors.

Compiles the same spec into (a) the DES engine — the planning/search
tool — and (b) the live serving runtime (``repro.serve``), which
executes *actual records* through real Pipeline operators on a
deterministic virtual-time event loop, with an ``OnlineController``
re-placing services at epoch boundaries and a ``CalibrationLoop``
learning from the runtime's *measured* residuals. Then prints the
sim-vs-real gap — the quantity ``benchmarks/bench_serve.py`` gates on.

  PYTHONPATH=src python examples/serve_pipeline_demo.py [--smoke]
"""
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))   # repro without PYTHONPATH
sys.path.insert(0, _ROOT)                        # benchmarks package

from benchmarks.bench_serve import _live_spec                    # noqa: E402
from repro.online import OnlineController                        # noqa: E402
from repro.serve import serve_scenario                           # noqa: E402

SMOKE = "--smoke" in sys.argv

spec = _live_spec(smoke=SMOKE)
print(f"scenario: {spec.name} (spec -> serve_scenario -> run)")
print(f"pipeline DAG: {spec.topology()}")
print(f"sites: {[s.name for s in spec.sites]}, "
      f"outages: {spec.outage_map()}\n")

# ---- live serving: real records, live re-placement, measured feedback
ctl = OnlineController(calibrate=True)
runtime = serve_scenario(spec)
real = runtime.run(ctl)

print("live epochs (measured rates drive the controller):")
for m in real.epochs:
    rates = ", ".join(f"{s}={r:.2f}/s"
                      for s, r in sorted(m["rates_measured"].items()))
    migs = "".join(f" migrate {g['service']}:{g['src']}->{g['dst']}"
                   f" (+{g['stall_s']}s stall)" for g in m["migrations"])
    print(f"  [{m['t0']:>6.0f}-{m['t1']:>6.0f}s] plan {m['plan']:32s} "
          f"{rates}{migs}")

# ---- the same spec through the DES engine, same controller family
sim = spec.compile().run(OnlineController(calibrate=True))

print(f"\nVoS   simulated={sim.vos:.2f}  served={real.vos:.2f}  "
      f"gap={abs(real.vos - sim.vos):.4f}")
print(f"p95   simulated={sim.latency_p95:.3f}s  "
      f"served={real.latency_p95:.3f}s")
print(f"fires served: {real.fires_completed}/{real.fires_total} "
      f"(dropped {real.fires_dropped}), migrations: {real.migrations}")
print(f"record conservation: {real.ledger.conserved()}")

cal = ctl.calibration
print(f"\ncalibration from measured residuals: "
      f"{cal.observations} epoch observations")
if cal.history:
    for svc, corr in cal.history[-1]["corrections"].items():
        print(f"  {svc:10s} edge={corr['edge']} dc={corr['dc']}")

if SMOKE:
    assert real.ledger.conserved(), "serving ledger must conserve"
    assert cal.observations >= 2, "calibration must see measured epochs"
    print("\nsmoke: OK")
