"""Serving example: batched prefill + greedy decode with KV/SSM caches for
three different architecture families (dense GQA, MoE, attention-free SSD).

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import serve_demo

for arch in ("qwen3-1.7b", "olmoe-1b-7b", "mamba2-1.3b"):
    serve_demo(arch, batch=4, prompt_len=64, gen=16)
print("OK: three families served through the same prefill/decode API.")
